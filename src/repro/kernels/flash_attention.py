"""Pallas TPU kernel: flash (online-softmax) causal/windowed attention.

The dry-run roofline shows full attention's S×T f32 score tensor is the
dominant memory term for every dense arch at train_4k/prefill_32k
(EXPERIMENTS.md §Roofline).  XLA alone cannot keep the score block
VMEM-resident across the max/exp/sum/PV chain — that fusion is exactly
what a hand kernel buys: per (batch, head, q-block) program, stream KV
in blocks, maintain running max/normalizer, touch HBM only for
q/k/v/out.

Grid: (B, H, S/qb).  VMEM per program (qb=128, kb=128, D<=128, T<=8k):
q [qb,D] + k,v blocks [kb,D] + acc [qb,D] + scores [qb,kb] ≈ 200 KiB.

GQA: the wrapper maps query head h to kv head h // (H/Hkv); the kernel
itself sees one q head against one kv head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kb: int, causal: bool,
                  window: int, scale: float):
    qb, D = q_ref.shape[-2:]
    T = k_ref.shape[-2]
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale              # [qb, D]
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)

    nkb = T // kb

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, 0, pl.dslice(j * kb, kb)].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * kb, kb)].astype(jnp.float32)
        s = q @ k.T                                          # [qb, kb]
        k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
        mask = jnp.ones((qb, kb), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * corr + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((qb, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb, 1), jnp.float32)
    a0 = jnp.zeros((qb, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           qb: int = 128, kb: int = 128,
                           interpret: bool = True):
    """q [B,H,S,D], k/v [B,Hkv,T,D] with H a multiple of Hkv.

    Returns [B,H,S,D].  S must divide by qb and T by kb (wrapper pads).
    """
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_flash_kernel, kb=kb, causal=causal,
                             window=window, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B, H, S // qb),
        in_specs=[
            pl.BlockSpec((1, 1, qb, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    qb: int = 128, kb: int = 128, interpret: bool = True):
    """Padding wrapper: arbitrary S/T (pad keys get masked out by the
    causal/positional logic as long as padding is on the right and
    causal=True; for non-causal, T must already divide)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    qb = min(qb, max(8, 1 << (S - 1).bit_length() if S < qb else qb))
    kb = min(kb, max(8, 1 << (T - 1).bit_length() if T < kb else kb))
    ps, pt = (-S) % qb, (-T) % kb
    if ps:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, ps), (0, 0)))
    if pt:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pt), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pt), (0, 0)))
        assert causal or window, "non-causal padding would attend to pads"
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 qb=qb, kb=kb, interpret=interpret)
    return out[:, :, :S]


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """jnp oracle."""
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = H // Hkv
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q, kx).astype(jnp.float32)
    s = s / math.sqrt(D)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, vx).astype(q.dtype)
