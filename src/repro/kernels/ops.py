"""jit'd public wrappers for the aggregation kernels.

On TPU the Pallas kernels run compiled (interpret=False); everywhere
else (this CPU container, unit tests) they run in interpret mode or
fall back to the jnp reference — selected once at import.  Both paths
are numerically validated against ref.py in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .brsgd_stats import brsgd_stats_pallas, cwise_median_pallas, masked_mean_pallas

_BACKEND = jax.default_backend()
_INTERPRET = _BACKEND != "tpu"
# Pallas interpret mode is Python-slow for large d; production (TPU) runs
# compiled.  On CPU we default to the jnp reference for speed and keep
# the interpret path exercised by the kernel test-suite.
_USE_PALLAS_DEFAULT = _BACKEND == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def brsgd_stats(G, use_pallas: bool = _USE_PALLAS_DEFAULT, d_blk: int = 2048):
    """G [m,d] -> (median [d], mean [d], scores [m], l1 [m])."""
    if use_pallas:
        return brsgd_stats_pallas(G, d_blk=d_blk, interpret=_INTERPRET)
    return ref.brsgd_stats_ref(G)


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def masked_mean(G, mask, use_pallas: bool = _USE_PALLAS_DEFAULT, d_blk: int = 2048):
    if use_pallas:
        return masked_mean_pallas(G, mask, d_blk=d_blk, interpret=_INTERPRET)
    return ref.masked_mean_ref(G, mask)


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def cwise_median(G, use_pallas: bool = _USE_PALLAS_DEFAULT, d_blk: int = 2048):
    if use_pallas:
        return cwise_median_pallas(G, d_blk=d_blk, interpret=_INTERPRET)
    return ref.cwise_median_ref(G)
