"""jit'd public wrappers for the aggregation kernels.

On TPU the Pallas kernels run compiled (interpret=False); everywhere
else (this CPU container, unit tests) they run in interpret mode or
fall back to the jnp reference — selected once at import.  Both paths
are numerically validated against ref.py in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .brsgd_stats import (brsgd_partials_pallas, brsgd_stats_pallas,
                          cwise_median_pallas, fused_stats_pallas,
                          masked_mean_pallas, select_mean_pallas,
                          trimmed_mean_pallas)

_BACKEND = jax.default_backend()
_INTERPRET = _BACKEND != "tpu"
# Pallas interpret mode is Python-slow for large d; production (TPU) runs
# compiled.  On CPU we default to the jnp reference for speed and keep
# the interpret path exercised by the kernel test-suite.
_USE_PALLAS_DEFAULT = _BACKEND == "tpu"


def default_use_pallas() -> bool:
    """Import-time kernel-vs-reference default (True iff on TPU)."""
    return _USE_PALLAS_DEFAULT


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def brsgd_stats(G, use_pallas: bool = _USE_PALLAS_DEFAULT, d_blk: int = 2048):
    """G [m,d] -> (median [d], mean [d], scores [m], l1 [m])."""
    if use_pallas:
        return brsgd_stats_pallas(G, d_blk=d_blk, interpret=_INTERPRET)
    return ref.brsgd_stats_ref(G)


@functools.partial(jax.jit, static_argnames=("needs", "axis", "use_pallas",
                                             "d_blk"))
def fused_stats(G, needs: tuple, axis: int = 0,
                use_pallas: bool = _USE_PALLAS_DEFAULT,
                d_blk: int = 2048, valid=None, rows=None,
                refs=None) -> dict:
    """Fused statistics pass: any subset of ``ref.STAT_NAMES`` from one
    read of G (DESIGN.md §Perf).

    ``axis`` indexes the m workers; G may be N-D (blocked-scope views
    keep the worker axis mid-leaf).  On TPU the worker-major 2-D case
    runs the single-HBM-read Pallas kernel; everywhere else the jnp
    reference shares ONE bitonic sorted-rows pass across the requested
    statistics.  ``needs`` must be hashable (tuple/frozenset); unknown
    names are rejected by the engine registry before reaching here.

    ``valid`` ([m] 0/1) switches to the elastic masked pass (DESIGN.md
    §Elastic): statistics of the active workers only, dropped slots as
    exact zeros.  ``rows``/``refs`` are the streaming-accumulator hooks
    (per-arrival-bucket output slots / shared active-set invariants) —
    see ``engine.stream_leaf_stats``.  The Pallas kernels assume a full
    worker set, so masked calls always take the jnp reference.
    """
    needs = tuple(n for n in ref.STAT_NAMES if n in needs)
    if not needs:
        return {}
    if valid is not None:
        return ref.masked_fused_stats_ref(G, needs, valid, axis=axis,
                                          rows=rows, refs=refs)
    if use_pallas and axis == 0 and G.ndim == 2:
        return fused_stats_pallas(G, needs, d_blk=d_blk,
                                  interpret=_INTERPRET)
    return ref.fused_stats_ref(G, needs, axis=axis)


def masked_stat_refs(G, needs: tuple, valid, axis: int = 0) -> dict:
    """Shared active-set invariants for the streaming accumulator — see
    ``ref.masked_stat_refs`` (computed once per leaf, reused by every
    arrival bucket's ``fused_stats(..., rows=bucket, refs=...)``)."""
    needs = tuple(n for n in ref.STAT_NAMES if n in needs)
    return ref.masked_stat_refs(G, needs, valid, axis=axis)


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def brsgd_partials(G, use_pallas: bool = _USE_PALLAS_DEFAULT,
                   d_blk: int = 2048):
    """G [m,d] -> (scores [m], l1 [m]) — the stats pass without the
    [d]-sized median/mean outputs (first pass of the fused BrSGD path)."""
    st = fused_stats(G, ("scores", "l1"), use_pallas=use_pallas, d_blk=d_blk)
    return st["scores"], st["l1"]


@functools.partial(jax.jit, static_argnames=("beta", "use_pallas", "d_blk"))
def brsgd_select_mean(G, scores, l1, beta: float, threshold,
                      use_pallas: bool = _USE_PALLAS_DEFAULT,
                      d_blk: int = 2048):
    """Fused C1∩C2 selection + masked mean (second pass of the fused
    BrSGD path).  Returns (aggregate [d], selection weights [m])."""
    if use_pallas:
        return select_mean_pallas(G, scores, l1, beta, threshold,
                                  d_blk=d_blk, interpret=_INTERPRET)
    # jnp fallback: the shared selection math + deterministic combine
    sel, _, _, _ = ref.brsgd_select_mask(scores, l1, beta, threshold)
    w = sel.astype(jnp.float32)
    return ref.masked_mean_det(G, w), w


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def masked_mean(G, mask, use_pallas: bool = _USE_PALLAS_DEFAULT,
                d_blk: int = 2048):
    """Masked (bool) or weighted (f32) row mean: Σ w_i g_i / Σ w_i."""
    if use_pallas:
        return masked_mean_pallas(G, mask, d_blk=d_blk, interpret=_INTERPRET)
    return ref.masked_mean_ref(G, mask)


@functools.partial(jax.jit, static_argnames=("use_pallas", "d_blk"))
def cwise_median(G, use_pallas: bool = _USE_PALLAS_DEFAULT, d_blk: int = 2048,
                 valid=None):
    if valid is not None:
        return ref.masked_cwise_median_ref(G, valid)
    if use_pallas:
        return cwise_median_pallas(G, d_blk=d_blk, interpret=_INTERPRET)
    return ref.cwise_median_ref(G)


@functools.partial(jax.jit, static_argnames=("trim_frac", "use_pallas",
                                             "d_blk"))
def trimmed_mean(G, trim_frac: float, use_pallas: bool = _USE_PALLAS_DEFAULT,
                 d_blk: int = 2048, valid=None):
    """Coordinate-wise trimmed mean (k = ⌊trim_frac·m⌋ per side; with a
    ``valid`` mask both counts are over the active rows, traced)."""
    if valid is not None:
        return ref.masked_trimmed_mean_ref(G, trim_frac, valid)
    if use_pallas:
        return trimmed_mean_pallas(G, trim_frac, d_blk=d_blk,
                                   interpret=_INTERPRET)
    return ref.trimmed_mean_ref(G, trim_frac)
