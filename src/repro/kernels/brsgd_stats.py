"""Pallas TPU kernel: fused BrSGD aggregation statistics.

The aggregation is memory-bound (O(1) FLOP per byte of G), so the win
on TPU is reading G from HBM ONCE and producing all per-column /
per-worker statistics in a single pass:

  * column mean                       a_c           [d]
  * coordinate-wise median            g_med         [d]
  * majority-score partial sums       s_i (partial) [grid, m]
  * l1-distance-to-median partials    l1_i(partial) [grid, m]

Tiling: grid over d; each step loads a (m, d_blk) tile into VMEM
(m <= 64 workers is a compile-time constant; d_blk default 2048 →
m*d_blk*4B = 512 KiB << 16 MiB VMEM).  The median uses a bitonic
sorting network over the (padded pow2) worker axis — static
compare-exchange stages of jnp.minimum/maximum, MXU-free, fully
vectorized over the d_blk lanes.

Per-worker partials are emitted per grid step and reduced by the ops.py
wrapper (they are tiny: [grid, m]).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_stages(n: int):
    """Compare-exchange index pairs for a bitonic sort network of size n
    (n a power of two).  Returns list of (i, j) stage arrays."""
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            pairs = []
            for i in range(n):
                l = i ^ j
                if l > i:
                    asc = (i & k) == 0
                    pairs.append((i, l, asc))
            stages.append(pairs)
            j //= 2
        k *= 2
    return stages


def _sorted_rows(x, m: int):
    """Sort rows of x [mp, d_blk] (mp = padded pow2; rows >= m are +inf)
    along axis 0 with a static bitonic network."""
    mp = x.shape[0]
    rows = [x[i] for i in range(mp)]
    for stage in _bitonic_stages(mp):
        for i, l, asc in stage:
            lo = jnp.minimum(rows[i], rows[l])
            hi = jnp.maximum(rows[i], rows[l])
            rows[i], rows[l] = (lo, hi) if asc else (hi, lo)
    return rows


def _stats_kernel(g_ref, med_ref, mean_ref, score_ref, l1_ref, *, m: int):
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    d_blk = g.shape[1]
    # ---- column mean & majority score ----
    mean_c = jnp.sum(g, axis=0, keepdims=True) / m           # [1, d_blk]
    above = g >= mean_c
    n_above = jnp.sum(above.astype(jnp.int32), axis=0, keepdims=True)
    majority_is_above = (n_above * 2) >= m
    M = jnp.where(majority_is_above, above, ~above)
    score_ref[0, :] = jnp.sum(M.astype(jnp.float32), axis=1)
    mean_ref[...] = mean_c[0]
    # ---- median via bitonic network (pad workers to pow2 with +inf) ----
    mp = 1 << max(1, math.ceil(math.log2(m)))
    if mp > m:
        pad = jnp.full((mp - m, d_blk), jnp.inf, jnp.float32)
        gp = jnp.concatenate([g, pad], axis=0)
    else:
        gp = g
    rows = _sorted_rows(gp, m)
    med = rows[(m - 1) // 2] if m % 2 else 0.5 * (rows[m // 2 - 1] + rows[m // 2])
    med_ref[...] = med
    # ---- l1 partials ----
    l1_ref[0, :] = jnp.sum(jnp.abs(g - med[None, :]), axis=1)


def brsgd_stats_pallas(G, d_blk: int = 2048, interpret: bool = True):
    """G: [m, d] -> (median [d], mean [d], scores [m], l1 [m])."""
    m, d = G.shape
    d_blk = min(d_blk, d)
    pad = (-d) % d_blk
    if pad:
        # pad columns with zeros: median/mean of a zero column is zero,
        # the extra score/l1 contributions are constant across workers
        # for score (all equal -> majority=everyone) and zero for l1 —
        # score gets +pad for every worker, which we subtract below.
        G = jnp.pad(G, ((0, 0), (0, pad)))
    dp = G.shape[1]
    grid = dp // d_blk
    kern = functools.partial(_stats_kernel, m=m)
    med, mean, score_p, l1_p = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((d_blk,), lambda i: (i,)),
            pl.BlockSpec((d_blk,), lambda i: (i,)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((grid, m), jnp.float32),
            jax.ShapeDtypeStruct((grid, m), jnp.float32),
        ],
        interpret=interpret,
    )(G)
    scores = jnp.sum(score_p, axis=0)
    if pad:
        scores = scores - pad                                # zero-pad columns scored 1 for all
    l1 = jnp.sum(l1_p, axis=0)
    return med[:d], mean[:d], scores, l1


def masked_mean_kernel(g_ref, w_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    w = w_ref[...].astype(jnp.float32)                       # [m]
    out_ref[...] = w @ g


def masked_mean_pallas(G, mask, d_blk: int = 2048, interpret: bool = True):
    """Mean over selected rows.  mask: [m] bool."""
    m, d = G.shape
    d_blk = min(d_blk, d)
    pad = (-d) % d_blk
    if pad:
        G = jnp.pad(G, ((0, 0), (0, pad)))
    dp = G.shape[1]
    w = mask.astype(jnp.float32)
    out = pl.pallas_call(
        masked_mean_kernel,
        grid=(dp // d_blk,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i)),
                  pl.BlockSpec((m,), lambda i: (0,))],
        out_specs=pl.BlockSpec((d_blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(G, w)
    return out[:d] / jnp.maximum(jnp.sum(w), 1.0)


def cwise_median_pallas(G, d_blk: int = 2048, interpret: bool = True):
    """Coordinate-wise median baseline (same bitonic machinery)."""
    med, _, _, _ = brsgd_stats_pallas(G, d_blk, interpret)
    return med
