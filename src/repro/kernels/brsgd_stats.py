"""Pallas TPU kernels: fused BrSGD aggregation statistics + combine.

The aggregation is memory-bound (O(1) FLOP per byte of G), so the win
on TPU is minimizing HBM traffic over G.  Kernels here:

* ``fused_stats_pallas``      ONE pass over G emitting any subset of
                              ``ref.STAT_NAMES`` (majority-score, l1,
                              d2med partials [grid, m]; Gram partials
                              [grid, m, m]) — every statistic an
                              aggregator declares costs a single shared
                              HBM read, and the coordinate-wise median
                              inside the tile is computed once for
                              l1 AND d2med (the one-sort contract,
                              DESIGN.md §Perf).
* ``brsgd_stats_pallas``      one pass producing column mean [d],
                              coordinate-wise median [d], majority-score
                              partials and l1 partials [grid, m].
* ``brsgd_partials_pallas``   fused_stats_pallas over (scores, l1) —
                              no [d]-sized median/mean HBM writes.
                              First pass of the fused BrSGD path.
* ``select_mean_pallas``      second pass fusing the C1∩C2 selection
                              (recomputed per grid step from the [m]
                              score/l1 vectors — trivially cheap) with
                              the masked-mean row combine.  With the
                              partials pass, local BrSGD streams G from
                              HBM exactly twice and never round-trips a
                              [d]-sized intermediate (the seed path made
                              three d-sized HBM traversals: stats read
                              of G + median/mean writes, then the
                              masked-mean read).
* ``masked_mean_pallas``      standalone masked/weighted row mean.
* ``trimmed_mean_pallas``     coordinate-wise trimmed mean via the same
                              bitonic sorting network.

Tiling: grid over d; each step loads a (m, d_blk) tile into VMEM
(m <= 64 workers is a compile-time constant; d_blk default 2048 →
m*d_blk*4B = 512 KiB << 16 MiB VMEM).  The median/trim sort uses a
bitonic network over the (padded pow2) worker axis — static
compare-exchange stages of jnp.minimum/maximum, MXU-free, fully
vectorized over the d_blk lanes.

Per-worker partials are emitted per grid step and reduced by the ops.py
wrapper (they are tiny: [grid, m]).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sorted_rows(x, m: int):
    """Sort rows of x [mp, d_blk] (mp = padded pow2; rows >= m are +inf)
    along axis 0 with a static bitonic network (the SAME network the jnp
    reference path runs — ref.bitonic_stages is the one copy)."""
    mp = x.shape[0]
    rows = [x[i] for i in range(mp)]
    for stage in ref.bitonic_stages(mp):
        for i, l, asc in stage:
            lo = jnp.minimum(rows[i], rows[l])
            hi = jnp.maximum(rows[i], rows[l])
            rows[i], rows[l] = (lo, hi) if asc else (hi, lo)
    return rows


def _pad_pow2(g, m: int):
    """Pad the worker axis to the next power of two with +inf."""
    mp = 1 << max(1, math.ceil(math.log2(m)))
    if mp > m:
        pad = jnp.full((mp - m, g.shape[1]), jnp.inf, jnp.float32)
        return jnp.concatenate([g, pad], axis=0)
    return g


def _majority_scores(g, m: int):
    """(column mean [d_blk], per-worker majority-score partials [m])."""
    mean_c = jnp.sum(g, axis=0, keepdims=True) / m           # [1, d_blk]
    above = g >= mean_c
    n_above = jnp.sum(above.astype(jnp.int32), axis=0, keepdims=True)
    majority_is_above = (n_above * 2) >= m
    M = jnp.where(majority_is_above, above, ~above)
    return mean_c[0], jnp.sum(M.astype(jnp.float32), axis=1)


def _median_rows(g, m: int):
    """Coordinate-wise median [d_blk] via the bitonic network."""
    rows = _sorted_rows(_pad_pow2(g, m), m)
    if m % 2:
        return rows[(m - 1) // 2]
    return 0.5 * (rows[m // 2 - 1] + rows[m // 2])


def _stats_kernel(g_ref, med_ref, mean_ref, score_ref, l1_ref, *, m: int):
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    mean_c, scores = _majority_scores(g, m)
    mean_ref[...] = mean_c
    score_ref[0, :] = scores
    med = _median_rows(g, m)
    med_ref[...] = med
    l1_ref[0, :] = jnp.sum(jnp.abs(g - med[None, :]), axis=1)


def _fused_stats_kernel(g_ref, *out_refs, m: int, needs: tuple):
    """One tile pass emitting the requested subset of ref.STAT_NAMES.

    ``needs`` is a canonical-order tuple matching ``out_refs``.  The
    tile's coordinate-wise median is computed at most once and shared by
    l1/d2med; the Gram partial is the tile's g @ gᵀ (summed over the
    grid by the wrapper, like the other partials)."""
    outs = dict(zip(needs, out_refs))
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    if "scores" in outs:
        _, scores = _majority_scores(g, m)
        outs["scores"][0, :] = scores
    if "l1" in outs or "d2med" in outs:
        diff = g - _median_rows(g, m)[None, :]
        if "l1" in outs:
            outs["l1"][0, :] = jnp.sum(jnp.abs(diff), axis=1)
        if "d2med" in outs:
            outs["d2med"][0, :] = jnp.sum(diff * diff, axis=1)
    if "gram" in outs:
        outs["gram"][0, :, :] = jnp.dot(g, g.T)


def _pad_cols(G, d_blk: int):
    """Zero-pad the dim axis to a multiple of d_blk.  A zero column's
    median/mean is zero, its l1/trim contribution is zero, and its score
    contribution is +1 for EVERY worker (all tie at the mean) — the
    wrappers subtract that uniform offset."""
    d = G.shape[1]
    pad = (-d) % d_blk
    if pad:
        G = jnp.pad(G, ((0, 0), (0, pad)))
    return G, pad


def brsgd_stats_pallas(G, d_blk: int = 2048, interpret: bool = True):
    """G: [m, d] -> (median [d], mean [d], scores [m], l1 [m])."""
    m, d = G.shape
    d_blk = min(d_blk, d)
    G, pad = _pad_cols(G, d_blk)
    dp = G.shape[1]
    grid = dp // d_blk
    kern = functools.partial(_stats_kernel, m=m)
    med, mean, score_p, l1_p = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((d_blk,), lambda i: (i,)),
            pl.BlockSpec((d_blk,), lambda i: (i,)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((grid, m), jnp.float32),
            jax.ShapeDtypeStruct((grid, m), jnp.float32),
        ],
        interpret=interpret,
    )(G)
    scores = jnp.sum(score_p, axis=0)
    if pad:
        scores = scores - pad                                # zero-pad columns scored 1 for all
    l1 = jnp.sum(l1_p, axis=0)
    return med[:d], mean[:d], scores, l1


def fused_stats_pallas(G, needs, d_blk: int = 2048,
                       interpret: bool = True) -> dict:
    """G [m, d] -> {stat: summed partial} for any subset of
    ref.STAT_NAMES, in ONE grid pass over G (one HBM read total,
    however many statistics the aggregator declared).

    Per-worker partials ([grid, m]; [grid, m, m] for gram) are emitted
    per grid step and reduced here — they are tiny next to G.  Zero-pad
    columns contribute +1 per worker to ``scores`` (subtracted) and
    exactly 0 to l1/d2med/gram."""
    m, d = G.shape
    needs = tuple(n for n in ref.STAT_NAMES if n in needs)
    d_blk = min(d_blk, d)
    G, pad = _pad_cols(G, d_blk)
    grid = G.shape[1] // d_blk
    out_specs, out_shape = [], []
    for n in needs:
        if n == "gram":
            out_specs.append(pl.BlockSpec((1, m, m), lambda i: (i, 0, 0)))
            out_shape.append(jax.ShapeDtypeStruct((grid, m, m), jnp.float32))
        else:
            out_specs.append(pl.BlockSpec((1, m), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((grid, m), jnp.float32))
    kern = functools.partial(_fused_stats_kernel, m=m, needs=needs)
    parts = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(G)
    out = {}
    for n, p in zip(needs, parts if isinstance(parts, (list, tuple))
                    else [parts]):
        s = jnp.sum(p, axis=0)
        if n == "scores" and pad:
            s = s - pad
        out[n] = s
    return out


def brsgd_partials_pallas(G, d_blk: int = 2048, interpret: bool = True):
    """G: [m, d] -> (scores [m], l1 [m]) with no [d]-sized outputs —
    the fused-stats pass over exactly BrSGD's declared statistics."""
    st = fused_stats_pallas(G, ("scores", "l1"), d_blk=d_blk,
                            interpret=interpret)
    return st["scores"], st["l1"]


def _select_mean_kernel(g_ref, sl_ref, pr_ref, out_ref, w_ref, *, m: int):
    """C1∩C2 selection (paper Alg. 2) + masked row sum, fused.

    sl: [2, m] (scores; l1).  pr: [2] (kth score; 2·𝔗).  Recomputing the
    [m]-sized selection per grid step costs nothing next to the (m,
    d_blk) tile load and keeps the whole second phase in one kernel."""
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    scores = sl_ref[0, :]
    l1 = sl_ref[1, :]
    c1 = l1 <= pr_ref[1]
    c2 = scores >= pr_ref[0]
    sel = jnp.logical_and(c1, c2)
    sel = jnp.where(jnp.any(sel), sel, c2)    # C1∩C2 empty -> fall back to C2
    w = sel.astype(jnp.float32)
    w_ref[...] = w
    out_ref[...] = w @ g


def select_mean_pallas(G, scores, l1, beta: float, threshold,
                       d_blk: int = 2048, interpret: bool = True):
    """Fused second pass of local BrSGD: selection + masked mean.

    Returns (aggregate [d], selection weights [m]).  Selection semantics
    are identical to ``engine.brsgd_select`` (same IEEE comparisons on
    the same inputs)."""
    m, d = G.shape
    d_blk = min(d_blk, d)
    G, _pad = _pad_cols(G, d_blk)            # zero pad contributes 0 to w @ g
    dp = G.shape[1]
    kth, T = ref.brsgd_thresholds(scores, l1, beta, threshold)
    sl = jnp.stack([scores, l1]).astype(jnp.float32)         # [2, m]
    pr = jnp.stack([kth, 2.0 * T]).astype(jnp.float32)       # [2]
    kern = functools.partial(_select_mean_kernel, m=m)
    acc, w = pl.pallas_call(
        kern,
        grid=(dp // d_blk,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i)),
                  pl.BlockSpec((2, m), lambda i: (0, 0)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((d_blk,), lambda i: (i,)),
                   pl.BlockSpec((m,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((dp,), jnp.float32),
                   jax.ShapeDtypeStruct((m,), jnp.float32)],
        interpret=interpret,
    )(G, sl, pr)
    sw = jnp.sum(w)
    return acc[:d] / jnp.where(sw > 0, sw, 1.0), w


def masked_mean_kernel(g_ref, w_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    w = w_ref[...].astype(jnp.float32)                       # [m]
    out_ref[...] = w @ g


def masked_mean_pallas(G, mask, d_blk: int = 2048, interpret: bool = True):
    """Mean over selected rows.  mask: [m] bool, or f32 weights (the
    engine's weighted combine) — the denominator is Σw, guarded to 1
    when the mask is empty."""
    m, d = G.shape
    d_blk = min(d_blk, d)
    G, _pad = _pad_cols(G, d_blk)
    dp = G.shape[1]
    w = mask.astype(jnp.float32)
    out = pl.pallas_call(
        masked_mean_kernel,
        grid=(dp // d_blk,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i)),
                  pl.BlockSpec((m,), lambda i: (0,))],
        out_specs=pl.BlockSpec((d_blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(G, w)
    sw = jnp.sum(w)
    return out[:d] / jnp.where(sw > 0, sw, 1.0)


def cwise_median_pallas(G, d_blk: int = 2048, interpret: bool = True):
    """Coordinate-wise median baseline (same bitonic machinery)."""
    med, _, _, _ = brsgd_stats_pallas(G, d_blk, interpret)
    return med


def _trimmed_mean_kernel(g_ref, out_ref, *, m: int, k: int):
    g = g_ref[...].astype(jnp.float32)                       # [m, d_blk]
    rows = _sorted_rows(_pad_pow2(g, m), m)                  # +inf pad sorts last
    acc = rows[k]
    for i in range(k + 1, m - k):
        acc = acc + rows[i]
    out_ref[...] = acc / (m - 2 * k)


def trimmed_mean_pallas(G, trim_frac: float, d_blk: int = 2048,
                        interpret: bool = True):
    """Coordinate-wise trimmed mean (Yin et al. 2018): drop the k
    smallest and k largest per dimension, k = ⌊trim_frac·m⌋."""
    m, d = G.shape
    k = ref.trim_k(trim_frac, m)        # shared degenerate-trim guard
    d_blk = min(d_blk, d)
    G, _pad = _pad_cols(G, d_blk)       # zero columns trim to 0, sliced off
    dp = G.shape[1]
    kern = functools.partial(_trimmed_mean_kernel, m=m, k=k)
    out = pl.pallas_call(
        kern,
        grid=(dp // d_blk,),
        in_specs=[pl.BlockSpec((m, d_blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((d_blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(G)
    return out[:d]
