"""Pure-jnp oracles for the aggregation kernels.

These are the ground truth the Pallas kernels are validated against and
the fallback implementation on non-TPU backends.  All operate on the
gradient matrix ``G`` of shape [m, d] (m workers, d dimensions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def column_mean_ref(G):
    return jnp.mean(G.astype(jnp.float32), axis=0)


def cwise_median_ref(G):
    """Coordinate-wise median over workers (axis 0)."""
    return jnp.median(G.astype(jnp.float32), axis=0)


def majority_score_ref(G):
    """Paper Algorithm 2, Constraint-2 scores.

    Per column: split workers by the column mean; workers in the larger
    subset score 1 (ties at exactly m/2 favour the >= mean subset, per
    the paper's ``counter < m/2`` negation rule).  Score_i = row sum.
    """
    m = G.shape[0]
    Gf = G.astype(jnp.float32)
    mean_c = jnp.mean(Gf, axis=0, keepdims=True)             # [1,d]
    above = Gf >= mean_c                                     # [m,d]
    n_above = jnp.sum(above, axis=0, keepdims=True)          # [1,d]
    majority_is_above = n_above * 2 >= m                     # counter >= m/2
    M = jnp.where(majority_is_above, above, ~above)
    return jnp.sum(M.astype(jnp.float32), axis=1)            # [m]


def l1_to_median_ref(G, med=None):
    if med is None:
        med = cwise_median_ref(G)
    return jnp.sum(jnp.abs(G.astype(jnp.float32) - med[None]), axis=1)


def brsgd_stats_ref(G):
    """One fused pass: (median [d], mean [d], scores [m], l1 [m])."""
    med = cwise_median_ref(G)
    return med, column_mean_ref(G), majority_score_ref(G), l1_to_median_ref(G, med)


def masked_mean_ref(G, mask):
    """Mean of the selected rows.  mask: [m] bool/float."""
    w = mask.astype(jnp.float32)
    return (w @ G.astype(jnp.float32)) / jnp.maximum(jnp.sum(w), 1.0)


def trimmed_mean_ref(G, trim_frac: float):
    """Coordinate-wise trimmed mean (Yin et al. 2018 baseline)."""
    m = G.shape[0]
    k = int(trim_frac * m)
    Gs = jnp.sort(G.astype(jnp.float32), axis=0)
    if k:
        Gs = Gs[k:m - k]
    return jnp.mean(Gs, axis=0)
