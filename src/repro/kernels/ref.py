"""Pure-jnp oracles for the aggregation kernels.

These are the ground truth the Pallas kernels are validated against and
the fallback implementation on non-TPU backends.  All operate on the
gradient matrix ``G`` of shape [m, d] (m workers, d dimensions).

Determinism note: ``column_mean_ref``/``masked_mean_det`` accumulate
rows in a fixed sequential order (row 0, 1, …, m-1) and divide behind
an optimization barrier.  Rationale: XLA is free to reassociate plain
reduce-sums and to fold a constant divisor into a multiply-by-
reciprocal; both perturb the result by ~1 ulp, which is a relative
error of ~1e-4 on near-zero coordinates and broke the seed's
mean-equivalence tests.  The sequential order matches NumPy's
``np.add.reduce`` along axis 0, so ``mean`` is bit-identical to
``np.mean(G, axis=0)`` and ``masked_mean_det`` with a full mask is
bit-identical to ``mean``.  ``masked_mean_ref`` keeps the matvec form:
it is the oracle for the (blockwise-accumulating) Pallas kernel, which
is validated against it under tolerance.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

# Canonical names of the additive per-leaf aggregation statistics (the
# engine registry re-exports this; it lives here so the kernel layer can
# share it without a circular import).  Order is the canonical emission
# order of the fused-stats pass.
STAT_NAMES = ("scores", "l1", "d2med", "gram")


# ---------------------------------------------------------------------------
# one-sort contract: the shared sorted-rows pass (DESIGN.md §Perf)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def bitonic_stages(n: int):
    """Compare-exchange index pairs for a bitonic sorting network of
    size n (a power of two): tuple of stages, each a tuple of
    (i, j, ascending) pairs.  Shared by the jnp reference sort below and
    the Pallas kernels (kernels/brsgd_stats.py)."""
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            pairs = []
            for i in range(n):
                l = i ^ j
                if l > i:
                    pairs.append((i, l, (i & k) == 0))
            stages.append(tuple(pairs))
            j //= 2
        k *= 2
    return tuple(stages)


def sorted_worker_rows(G, axis: int = 0):
    """Worker slices of G sorted ascending along ``axis`` — a list of m
    arrays (f32, ``axis`` removed), via a static bitonic network of
    vectorized jnp.minimum/maximum stages.

    This is THE sort of the one-sort contract (DESIGN.md §Perf): every
    order statistic the reference path needs (coordinate-wise median,
    l1/d2med distances to it, trimmed mean) derives from this one pass.
    XLA lowers its CPU ``sort`` to scalar loops — at [8, 160k] the
    network is >100x faster and bit-identical on finite inputs (min/max
    networks don't totally order NaNs; callers assume finite data, as
    the Pallas kernels already do).  The worker count is a compile-time
    constant, so the network fully unrolls (O(m log^2 m) vector ops).
    """
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)
    m = x.shape[0]
    mp = 1 << max(1, math.ceil(math.log2(m)))
    rows = [x[i] for i in range(m)]
    rows += [jnp.full_like(rows[0], jnp.inf)] * (mp - m)   # pad sorts last
    for stage in bitonic_stages(mp):
        for i, l, asc in stage:
            lo = jnp.minimum(rows[i], rows[l])
            hi = jnp.maximum(rows[i], rows[l])
            rows[i], rows[l] = (lo, hi) if asc else (hi, lo)
    return rows[:m]


def median_from_sorted(rows):
    """Coordinate-wise median from :func:`sorted_worker_rows` output —
    identical to jnp.median on finite inputs (the two-middle average
    divides by 2 exactly)."""
    m = len(rows)
    if m % 2:
        return rows[m // 2]
    return 0.5 * (rows[m // 2 - 1] + rows[m // 2])


def sorted_worker_stack(G, axis: int = 0):
    """Full ascending sort along ``axis`` as one stacked [m, ...] array,
    running each bitonic stage as ONE vectorized permute+min+max+select
    over the whole stack.

    Complements :func:`sorted_worker_rows` for consumers that read MANY
    sorted rows (trimmed mean at larger m): the per-row network relies
    on XLA dead-code elimination, and XLA's CPU fusion re-computes the
    surviving compare-exchange cone once per consumer — O(m) duplication
    when all m rows are read.  Here every stage has a single
    producer-consumer edge, so the work stays O(m log² m) passes."""
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)
    m = x.shape[0]
    mp = 1 << max(1, math.ceil(math.log2(m)))
    if mp > m:
        pad = jnp.full((mp - m,) + x.shape[1:], jnp.inf, jnp.float32)
        x = jnp.concatenate([x, pad], axis=0)
    bshape = (mp,) + (1,) * (x.ndim - 1)
    for stage in bitonic_stages(mp):
        perm = np.arange(mp)
        keep_lo = np.zeros(mp, bool)
        for i, l, asc in stage:
            perm[i], perm[l] = l, i
            keep_lo[i], keep_lo[l] = asc, not asc
        partner = x[jnp.asarray(perm)]
        lo = jnp.minimum(x, partner)
        hi = jnp.maximum(x, partner)
        x = jnp.where(jnp.asarray(keep_lo).reshape(bshape), lo, hi)
    return x[:m]


def det_sum_rows(G):
    """Sequential f32 row sum (axis 0) — deterministic accumulation
    order, bit-identical to NumPy's np.add.reduce(G, axis=0)."""
    s, _ = jax.lax.scan(lambda c, r: (c + r, None), jnp.zeros_like(G[0]), G)
    return s


def _exact_div(x, den):
    # the barrier stops XLA constant-folding the divisor into a
    # multiply-by-reciprocal (which is ~1 ulp off true IEEE division)
    return x / jax.lax.optimization_barrier(den)


def column_mean_ref(G):
    Gf = G.astype(jnp.float32)
    return _exact_div(det_sum_rows(Gf), jnp.float32(Gf.shape[0]))


def cwise_median_ref(G, axis: int = 0):
    """Coordinate-wise median over workers (along ``axis``) — one
    bitonic sorted-rows pass, not an XLA sort."""
    return median_from_sorted(sorted_worker_rows(G, axis))


def fused_stats_ref(G, needs, axis: int = 0) -> dict:
    """One-pass fused statistics: any subset of :data:`STAT_NAMES` from
    a single shared sorted-rows pass (jnp reference of ops.fused_stats).

    G's ``axis`` indexes the m workers; every other dimension is reduced
    (the partials are additive over disjoint dimension ranges, so views
    over dim ranges sum — the ``engine.leaf_stats`` contract).  N-D
    views (blocked scope: worker axis mid-leaf) never reshape across the
    non-worker dims — only ``axis`` is moved, never merged.  The
    coordinate-wise median is computed at most once and shared by
    ``l1`` and ``d2med``; before this pass existed each statistic
    re-sorted G independently.

    The [d]-sized invariants (column mean, majority mask, median) are
    consumed through ``lax.scan``/``lax.map`` bodies rather than
    broadcast expressions: a loop body is a separate XLA computation, so
    the invariant is materialized ONCE.  Fusing the broadcast instead
    lets XLA's CPU fusion re-compute the whole producer (including the
    sort network) per worker row — measured ~m× the work at m = 64
    (DESIGN.md §Perf).
    """
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)         # [m, ...]
    m = x.shape[0]
    out = {}
    if "scores" in needs:
        mean_c = jnp.mean(x, axis=0)
        n_above, _ = jax.lax.scan(
            lambda c, g: (c + (g >= mean_c).astype(jnp.int32), None),
            jnp.zeros(x.shape[1:], jnp.int32), x)
        majority_is_above = n_above * 2 >= m
        out["scores"] = jax.lax.map(
            lambda g: jnp.sum(jnp.where(majority_is_above, g >= mean_c,
                                        g < mean_c).astype(jnp.float32)), x)
    if "l1" in needs or "d2med" in needs:
        med = median_from_sorted(sorted_worker_rows(x))
        def dists(g):
            diff = g - med
            return jnp.sum(jnp.abs(diff)), jnp.sum(diff * diff)
        l1, d2med = jax.lax.map(dists, x)
        if "l1" in needs:
            out["l1"] = l1
        if "d2med" in needs:
            out["d2med"] = d2med
    if "gram" in needs:
        # contract every non-worker dim: G @ G.T without reshaping the
        # leaf to [m, cols] (keeps model-sharded dims where they are)
        red = tuple(range(1, x.ndim))
        out["gram"] = jnp.tensordot(x, x, axes=(red, red))
    return out


def majority_score_ref(G):
    """Paper Algorithm 2, Constraint-2 scores.

    Per column: split workers by the column mean; workers in the larger
    subset score 1 (ties at exactly m/2 favour the >= mean subset, per
    the paper's ``counter < m/2`` negation rule).  Score_i = row sum.
    """
    m = G.shape[0]
    Gf = G.astype(jnp.float32)
    mean_c = jnp.mean(Gf, axis=0, keepdims=True)             # [1,d]
    above = Gf >= mean_c                                     # [m,d]
    n_above = jnp.sum(above, axis=0, keepdims=True)          # [1,d]
    majority_is_above = n_above * 2 >= m                     # counter >= m/2
    M = jnp.where(majority_is_above, above, ~above)
    return jnp.sum(M.astype(jnp.float32), axis=1)            # [m]


def l1_to_median_ref(G, med=None):
    if med is None:
        med = cwise_median_ref(G)
    return jnp.sum(jnp.abs(G.astype(jnp.float32) - med[None]), axis=1)


def brsgd_stats_ref(G):
    """One fused pass: (median [d], mean [d], scores [m], l1 [m])."""
    med = cwise_median_ref(G)
    return med, column_mean_ref(G), majority_score_ref(G), l1_to_median_ref(G, med)


def masked_mean_ref(G, mask):
    """Mean of the selected rows (matvec form — Pallas kernel oracle).
    mask: [m] bool/float; float weights give a weighted mean."""
    w = mask.astype(jnp.float32)
    sw = jnp.sum(w)
    return (w @ G.astype(jnp.float32)) / jnp.where(sw > 0, sw, 1.0)


def masked_mean_det(G, mask):
    """Weighted row mean with deterministic sequential accumulation (see
    module docstring): full-mask output is bit-identical to
    ``column_mean_ref``."""
    Gf = G.astype(jnp.float32)
    w = mask.astype(jnp.float32)
    s, _ = jax.lax.scan(lambda c, wr: (c + wr[0] * wr[1], None),
                        jnp.zeros_like(Gf[0]), (w, Gf))
    sw = jnp.sum(w)
    return _exact_div(s, jnp.where(sw > 0, sw, 1.0))


def rank_select(x, k: int):
    """k-th smallest value of the 1-D vector x (0-indexed) WITHOUT
    sorting: counting ranks.  An element is the k-th order statistic iff
    (# strictly smaller) <= k < (# smaller-or-equal); duplicates all
    satisfy the predicate with the same value, so the masked max is
    exact.  Equal to ``jnp.sort(x)[k]`` on finite inputs.

    Replaces the last O(m log m) replicated step of the BrSGD selection
    with O(m)-depth counting (the [m, m] comparison is m <= 64 bools —
    one vector op — while XLA's CPU sort is a scalar loop); the
    per-dimension work that dominates Algorithm 2 stays O(md).
    """
    lt = jnp.sum((x[None, :] < x[:, None]).astype(jnp.int32), axis=1)
    le = jnp.sum((x[None, :] <= x[:, None]).astype(jnp.int32), axis=1)
    hit = (lt <= k) & (k < le)
    return jnp.max(jnp.where(hit, x, -jnp.inf))


def quantile_nearest_index(q: float, m: int) -> int:
    """Index of the ``method='nearest'`` q-quantile of a sorted m-vector,
    with jnp.quantile's tie rule: the virtual index q·(m-1) rounds half
    DOWN (jax selects low_value when the high weight is exactly 0.5;
    numpy's banker's rounding differs at .5 — we pin the jax semantics
    the selection previously compiled to)."""
    virt = q * (m - 1)
    low = math.floor(virt)
    return low if (virt - low) <= 0.5 else low + 1


def brsgd_thresholds(scores, l1, beta: float, threshold):
    """Resolved C1/C2 cutoffs of paper Algorithm 2: (kth score, 𝔗).

    This and ``brsgd_select_mask`` are the ONE copy of the selection
    math — engine.brsgd_select, the fused Pallas wrapper and the jnp
    fused fallback all stage through here (they live below the core
    layer, so the kernels can share them without a circular import).
    Both cutoffs are :func:`rank_select` counting quantiles — no sort
    anywhere in the replicated phase.
    """
    m = scores.shape[0]
    k = max(1, math.ceil(beta * m))
    kth = rank_select(scores, m - k)
    T = jnp.where(threshold > 0, threshold,
                  rank_select(l1, quantile_nearest_index(0.25, m)))
    return kth, T


def brsgd_select_mask(scores, l1, beta: float, threshold):
    """C1∩C2 with the empty-set fallback to C2.
    Returns (selected, c1, c2, 𝔗) — all [m] bool except 𝔗."""
    kth, T = brsgd_thresholds(scores, l1, beta, threshold)
    c1 = l1 <= 2.0 * T
    c2 = scores >= kth
    sel = c1 & c2
    sel = jnp.where(jnp.any(sel), sel, c2)
    return sel, c1, c2, T


def trim_k(trim_frac: float, m: int) -> int:
    """Per-side trim count k = ⌊trim_frac·m⌋, guarded so at least one
    row survives (degenerate trims fall back to median-like k)."""
    k = int(trim_frac * m)
    if 2 * k >= m:
        k = (m - 1) // 2
    return k


# above this worker count the trimmed mean reads enough sorted rows
# that XLA's per-consumer re-fusion of the row-list network costs more
# than the stage-vectorized stack's extra full passes (measured
# crossover between m=32 and m=64 on CPU at d=160k)
_TRIM_STACK_MIN_M = 33


def trimmed_mean_ref(G, trim_frac: float):
    """Coordinate-wise trimmed mean (Yin et al. 2018 baseline): mean of
    the sorted rows k..m-k-1 from the shared sorted-rows pass — the
    row-list network (DCE-pruned) for small m, the stage-vectorized
    stack for larger m (see :func:`sorted_worker_stack`)."""
    m = G.shape[0]
    k = trim_k(trim_frac, m)
    if m >= _TRIM_STACK_MIN_M:
        S = sorted_worker_stack(G)
        return jnp.sum(S[k:m - k], axis=0) / (m - 2 * k)
    rows = sorted_worker_rows(G)
    acc = rows[k]
    for i in range(k + 1, m - k):
        acc = acc + rows[i]
    return acc / (m - 2 * k)


# ---------------------------------------------------------------------------
# elastic (masked) statistics: pad-to-max-m + validity mask
# ---------------------------------------------------------------------------
# Every function below takes ``valid`` ([m] 0/1) naming the ACTIVE
# worker slots of a padded round.  The masking contract is EXACT ZEROS,
# never NaN poison: dropped slots are zeroed with ``jnp.where`` (a
# multiplicative 0 * inf would be NaN), cutoffs and counts are quantiles
# over the active set only, and active counts are traced values — so ONE
# compiled graph serves every active-set size up to max_m.

def quantile_index_dyn(q: float, n):
    """Traced-count twin of :func:`quantile_nearest_index` — same
    virtual index and half-DOWN tie rule, for quorum-sized active sets
    whose count is a runtime value."""
    virt = q * (n.astype(jnp.float32) - 1.0)
    low = jnp.floor(virt)
    return jnp.where(virt - low <= 0.5, low, low + 1.0).astype(jnp.int32)


def masked_sorted_stack(x, valid):
    """:func:`sorted_worker_stack` with the invalid rows forced to +inf
    so they sink past the active ones: rows [0, n_active) of the result
    are the ascending sort of the ACTIVE values."""
    vb = valid.astype(bool).reshape((x.shape[0],) + (1,) * (x.ndim - 1))
    return sorted_worker_stack(jnp.where(vb, x, jnp.inf))


def masked_median_from_stack(S, n_active):
    """Coordinate-wise median over the first ``n_active`` sorted rows
    (dynamic two-middle average; odd counts read the middle row twice,
    and 0.5·(a+a) == a exactly).  +inf rows past the active prefix are
    replaced by exact zeros when n_active == 0 so downstream masked
    consumers never multiply 0 · inf."""
    na = jnp.maximum(n_active, 1)
    lo = jnp.take(S, (na - 1) // 2, axis=0)
    hi = jnp.take(S, na // 2, axis=0)
    med = 0.5 * (lo + hi)
    return jnp.where(jnp.isfinite(med), med, 0.0)


def masked_stat_refs(G, needs, valid, axis: int = 0) -> dict:
    """The [d]-space invariants of the active set — column mean +
    majority mask (``scores``), coordinate-wise median (``l1`` /
    ``d2med``) — plus the zeroed worker view.

    Computed ONCE per leaf and shared by every arrival bucket's partial
    (``engine.stream_leaf_stats``): per-worker stat rows are functions
    of the worker's own row and these fixed references only, which is
    what makes the streaming fold bit-exact with the bulk masked pass
    (disjoint output slots + IEEE ``x + 0.0 == x``)."""
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)          # [m, ...]
    v = valid.astype(jnp.float32)
    vb = v.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
    x = jnp.where(vb > 0, x, 0.0)
    na = jnp.sum(v)
    refs = {"x": x, "v": v, "na": na}
    if "scores" in needs:
        mean_c = _exact_div(det_sum_rows(x), jnp.maximum(na, 1.0))
        n_above, _ = jax.lax.scan(
            lambda c, gv: (c + gv[1] * (gv[0] >= mean_c).astype(jnp.float32),
                           None),
            jnp.zeros(x.shape[1:], jnp.float32), (x, v))
        refs["mean_c"] = mean_c
        refs["majority_is_above"] = n_above * 2.0 >= na
    if "l1" in needs or "d2med" in needs:
        refs["med"] = masked_median_from_stack(
            masked_sorted_stack(x, v), jnp.sum(v.astype(jnp.int32)))
    return refs


def masked_fused_stats_ref(G, needs, valid, axis: int = 0, rows=None,
                           refs=None) -> dict:
    """Masked variant of :func:`fused_stats_ref`: statistics of the
    active workers only, with every dropped slot an EXACT zero.

    ``rows`` ([m] 0/1, optional) restricts the OUTPUT slots: slots
    outside ``rows`` are zero even when valid — this is the
    per-arrival-bucket partial of the streaming accumulator.  ``refs``
    reuses a :func:`masked_stat_refs` result so all buckets of one leaf
    share identical active-set invariants.  Each output slot depends
    only on that worker's row and the shared refs, so partials over any
    partition of the active set fold (bit-exactly, by disjoint slots)
    into the bulk ``rows=None`` pass."""
    if refs is None:
        refs = masked_stat_refs(G, needs, valid, axis=axis)
    x, v = refs["x"], refs["v"]
    r = v if rows is None else v * rows.astype(jnp.float32)
    out = {}
    if "scores" in needs:
        mean_c = refs["mean_c"]
        maj = refs["majority_is_above"]
        out["scores"] = jax.lax.map(
            lambda gr: gr[1] * jnp.sum(
                jnp.where(maj, gr[0] >= mean_c, gr[0] < mean_c)
                .astype(jnp.float32)), (x, r))
    if "l1" in needs or "d2med" in needs:
        med = refs["med"]

        def dists(gr):
            diff = gr[0] - med
            return (gr[1] * jnp.sum(jnp.abs(diff)),
                    gr[1] * jnp.sum(diff * diff))

        l1, d2med = jax.lax.map(dists, (x, r))
        if "l1" in needs:
            out["l1"] = l1
        if "d2med" in needs:
            out["d2med"] = d2med
    if "gram" in needs:
        red = tuple(range(1, x.ndim))
        xr = jnp.where(r.reshape((x.shape[0],) + (1,) * (x.ndim - 1)) > 0,
                       x, 0.0)
        out["gram"] = jnp.tensordot(xr, x, axes=(red, red))
    return out


def masked_cwise_median_ref(G, valid, axis: int = 0):
    """Coordinate-wise median over the active rows."""
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)
    return masked_median_from_stack(masked_sorted_stack(x, valid),
                                    jnp.sum(valid.astype(jnp.int32)))


def masked_trimmed_mean_ref(G, trim_frac: float, valid, axis: int = 0):
    """Coordinate-wise trimmed mean over the active rows: per-side trim
    k = ⌊trim_frac·n_active⌋ with the :func:`trim_k` degeneracy guard,
    both counts traced."""
    x = jnp.moveaxis(G.astype(jnp.float32), axis, 0)
    m = x.shape[0]
    S = masked_sorted_stack(x, valid)
    na = jnp.sum(valid.astype(jnp.int32))
    k = (trim_frac * na.astype(jnp.float32)).astype(jnp.int32)
    k = jnp.where(2 * k >= na, jnp.maximum(na - 1, 0) // 2, k)
    ranks = jnp.arange(m).reshape((m,) + (1,) * (x.ndim - 1))
    kept = jnp.where((ranks >= k) & (ranks < na - k), S, 0.0)
    return _exact_div(det_sum_rows(kept),
                      jnp.maximum(na - 2 * k, 1).astype(jnp.float32))


def masked_brsgd_select(scores, l1, beta: float, threshold, valid):
    """Masked :func:`brsgd_select_mask`: both cutoffs are counting
    quantiles over the ACTIVE workers (k = ⌈β·n_active⌉ clamped ≥ 1;
    auto-𝔗 = lower quartile of the active l1 at the dynamic
    :func:`quantile_index_dyn`), and no mask ever selects a dropped
    worker.  With a full mask this reduces to the static selection (same
    cutoff values, same tie rules)."""
    m = scores.shape[0]
    v = valid.astype(bool)
    na = jnp.maximum(jnp.sum(v.astype(jnp.int32)), 1)
    k = jnp.clip(jnp.ceil(beta * na.astype(jnp.float32)).astype(jnp.int32),
                 1, na)
    # dropped slots take -inf scores / +inf l1, so active order
    # statistics sit in known rank windows of the full m-vector:
    # the k-th-from-top active score is ascending rank m - k
    kth = rank_select(jnp.where(v, scores, -jnp.inf), m - k)
    T = jnp.where(threshold > 0, threshold,
                  rank_select(jnp.where(v, l1, jnp.inf),
                              quantile_index_dyn(0.25, na)))
    c1 = v & (l1 <= 2.0 * T)
    c2 = v & (scores >= kth)
    sel = c1 & c2
    sel = jnp.where(jnp.any(sel), sel, c2)
    return sel, c1, c2, T
