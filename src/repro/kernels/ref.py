"""Pure-jnp oracles for the aggregation kernels.

These are the ground truth the Pallas kernels are validated against and
the fallback implementation on non-TPU backends.  All operate on the
gradient matrix ``G`` of shape [m, d] (m workers, d dimensions).

Determinism note: ``column_mean_ref``/``masked_mean_det`` accumulate
rows in a fixed sequential order (row 0, 1, …, m-1) and divide behind
an optimization barrier.  Rationale: XLA is free to reassociate plain
reduce-sums and to fold a constant divisor into a multiply-by-
reciprocal; both perturb the result by ~1 ulp, which is a relative
error of ~1e-4 on near-zero coordinates and broke the seed's
mean-equivalence tests.  The sequential order matches NumPy's
``np.add.reduce`` along axis 0, so ``mean`` is bit-identical to
``np.mean(G, axis=0)`` and ``masked_mean_det`` with a full mask is
bit-identical to ``mean``.  ``masked_mean_ref`` keeps the matvec form:
it is the oracle for the (blockwise-accumulating) Pallas kernel, which
is validated against it under tolerance.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def det_sum_rows(G):
    """Sequential f32 row sum (axis 0) — deterministic accumulation
    order, bit-identical to NumPy's np.add.reduce(G, axis=0)."""
    s, _ = jax.lax.scan(lambda c, r: (c + r, None), jnp.zeros_like(G[0]), G)
    return s


def _exact_div(x, den):
    # the barrier stops XLA constant-folding the divisor into a
    # multiply-by-reciprocal (which is ~1 ulp off true IEEE division)
    return x / jax.lax.optimization_barrier(den)


def column_mean_ref(G):
    Gf = G.astype(jnp.float32)
    return _exact_div(det_sum_rows(Gf), jnp.float32(Gf.shape[0]))


def cwise_median_ref(G):
    """Coordinate-wise median over workers (axis 0)."""
    return jnp.median(G.astype(jnp.float32), axis=0)


def majority_score_ref(G):
    """Paper Algorithm 2, Constraint-2 scores.

    Per column: split workers by the column mean; workers in the larger
    subset score 1 (ties at exactly m/2 favour the >= mean subset, per
    the paper's ``counter < m/2`` negation rule).  Score_i = row sum.
    """
    m = G.shape[0]
    Gf = G.astype(jnp.float32)
    mean_c = jnp.mean(Gf, axis=0, keepdims=True)             # [1,d]
    above = Gf >= mean_c                                     # [m,d]
    n_above = jnp.sum(above, axis=0, keepdims=True)          # [1,d]
    majority_is_above = n_above * 2 >= m                     # counter >= m/2
    M = jnp.where(majority_is_above, above, ~above)
    return jnp.sum(M.astype(jnp.float32), axis=1)            # [m]


def l1_to_median_ref(G, med=None):
    if med is None:
        med = cwise_median_ref(G)
    return jnp.sum(jnp.abs(G.astype(jnp.float32) - med[None]), axis=1)


def brsgd_stats_ref(G):
    """One fused pass: (median [d], mean [d], scores [m], l1 [m])."""
    med = cwise_median_ref(G)
    return med, column_mean_ref(G), majority_score_ref(G), l1_to_median_ref(G, med)


def masked_mean_ref(G, mask):
    """Mean of the selected rows (matvec form — Pallas kernel oracle).
    mask: [m] bool/float; float weights give a weighted mean."""
    w = mask.astype(jnp.float32)
    sw = jnp.sum(w)
    return (w @ G.astype(jnp.float32)) / jnp.where(sw > 0, sw, 1.0)


def masked_mean_det(G, mask):
    """Weighted row mean with deterministic sequential accumulation (see
    module docstring): full-mask output is bit-identical to
    ``column_mean_ref``."""
    Gf = G.astype(jnp.float32)
    w = mask.astype(jnp.float32)
    s, _ = jax.lax.scan(lambda c, wr: (c + wr[0] * wr[1], None),
                        jnp.zeros_like(Gf[0]), (w, Gf))
    sw = jnp.sum(w)
    return _exact_div(s, jnp.where(sw > 0, sw, 1.0))


def brsgd_thresholds(scores, l1, beta: float, threshold):
    """Resolved C1/C2 cutoffs of paper Algorithm 2: (kth score, 𝔗).

    This and ``brsgd_select_mask`` are the ONE copy of the selection
    math — engine.brsgd_select, the fused Pallas wrapper and the jnp
    fused fallback all stage through here (they live below the core
    layer, so the kernels can share them without a circular import).
    """
    m = scores.shape[0]
    k = max(1, math.ceil(beta * m))
    kth = jnp.sort(scores)[m - k]
    T = jnp.where(threshold > 0, threshold,
                  jnp.quantile(l1, 0.25, method="nearest"))
    return kth, T


def brsgd_select_mask(scores, l1, beta: float, threshold):
    """C1∩C2 with the empty-set fallback to C2.
    Returns (selected, c1, c2, 𝔗) — all [m] bool except 𝔗."""
    kth, T = brsgd_thresholds(scores, l1, beta, threshold)
    c1 = l1 <= 2.0 * T
    c2 = scores >= kth
    sel = c1 & c2
    sel = jnp.where(jnp.any(sel), sel, c2)
    return sel, c1, c2, T


def trimmed_mean_ref(G, trim_frac: float):
    """Coordinate-wise trimmed mean (Yin et al. 2018 baseline)."""
    m = G.shape[0]
    k = int(trim_frac * m)
    if 2 * k >= m:                      # degenerate trim: median-like guard
        k = (m - 1) // 2
    Gs = jnp.sort(G.astype(jnp.float32), axis=0)
    if k:
        Gs = Gs[k:m - k]
    return jnp.mean(Gs, axis=0)
