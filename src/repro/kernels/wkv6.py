"""Pallas TPU kernel: one WKV6 chunk (the §Perf hot spot).

The chunked-parallel WKV6 (models/rwkv6._wkv_chunked) is the dominant
compute of rwkv6 training after the hillclimb.  On TPU the win is
keeping the whole per-(batch, head) chunk pipeline — cumulative
log-decay, the [Q,Q] intra-chunk score matmul, the state update —
resident in VMEM, reading r/k/v/w once from HBM and writing y/S_out
once.  Grid: one program per (batch, head); VMEM working set for
Q=K=64 is a handful of 16 KiB tiles.

Math (matches _wkv_chunked / _wkv_scan — see models/rwkv6.py):

  c_t  = Σ_{s<=t} log w_s            (inclusive, per channel)
  ce_t = c_t - log w_t               (exclusive)
  A[t,j] = (r_t e^{ce_t - mid}) · (k_j e^{mid - c_j}),  j < t
  y_t  = Σ_{j<t} A[t,j] v_j + (r_t ⊙ u)·k_t v_t + (r_t e^{ce_t})·S_in
  S'   = e^{c_Q} ⊙ S_in + Σ_j (k_j e^{c_Q - c_j}) v_j^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG_CLAMP = 40.0


def _wkv_chunk_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref,
                      y_ref, s_out_ref):
    r = r_ref[0, 0].astype(jnp.float32)          # [Q, K]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # [K]
    S_in = s_ref[0, 0].astype(jnp.float32)       # [K, K]

    logw = jnp.log(w)
    c = jnp.cumsum(logw, axis=0)                 # inclusive [Q, K]
    ce = c - logw                                # exclusive
    mid = 0.5 * c[-1:]
    r_dec = r * jnp.exp(jnp.clip(ce - mid, -LOG_CLAMP, LOG_CLAMP))
    k_grow = k * jnp.exp(jnp.clip(mid - c, -LOG_CLAMP, LOG_CLAMP))
    Q = r.shape[0]
    A = r_dec @ k_grow.T                         # [Q, Q]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32), k=-1)
    y = (A * tri) @ v
    y = y + jnp.sum(r * u[None] * k, axis=1, keepdims=True) * v
    r_state = r * jnp.exp(jnp.maximum(ce, -2 * LOG_CLAMP))
    y = y + r_state @ S_in
    k_end = k * jnp.exp(jnp.maximum(c[-1:] - c, -2 * LOG_CLAMP))
    S_out = (jnp.exp(jnp.maximum(c[-1], -2 * LOG_CLAMP))[:, None] * S_in
             + k_end.T @ v)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    s_out_ref[0, 0] = S_out.astype(s_out_ref.dtype)


def wkv6_chunk_pallas(r, k, v, w, u, S_in, interpret: bool = True):
    """One chunk for all (batch, head) programs.

    r/k/v/w: [B, H, Q, K]; u: [H, K]; S_in: [B, H, K, K].
    Returns (y [B,H,Q,K], S_out [B,H,K,K]).
    """
    B, H, Q, K = r.shape
    io = pl.BlockSpec((1, 1, Q, K), lambda b, h: (b, h, 0, 0))
    st = pl.BlockSpec((1, 1, K, K), lambda b, h: (b, h, 0, 0))
    uu = pl.BlockSpec((1, K), lambda b, h: (h, 0))
    y, S_out = pl.pallas_call(
        _wkv_chunk_kernel,
        grid=(B, H),
        in_specs=[io, io, io, io, uu, st],
        out_specs=[io, st],
        out_shape=[jax.ShapeDtypeStruct((B, H, Q, K), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, S_in)
    return y, S_out


def wkv6_chunk_ref(r, k, v, w, u, S_in):
    """jnp oracle: sequential recurrence over the chunk."""
    B, H, Q, K = r.shape
    f32 = jnp.float32
    S = S_in.astype(f32)
    ys = []
    for t in range(Q):
        rt, kt, vt, wt = (x[:, :, t].astype(f32) for x in (r, k, v, w))
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkj->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        ys.append(y)
    return jnp.stack(ys, axis=2), S
