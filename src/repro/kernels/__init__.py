from .ops import brsgd_stats, cwise_median, masked_mean
from . import ref
