from .ops import (brsgd_partials, brsgd_select_mean, brsgd_stats,
                  cwise_median, fused_stats, masked_mean, trimmed_mean)
from . import ref
