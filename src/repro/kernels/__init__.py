from .ops import (brsgd_partials, brsgd_select_mean, brsgd_stats,
                  cwise_median, masked_mean, trimmed_mean)
from . import ref
