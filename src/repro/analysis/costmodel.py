"""Analytic cost model + trace-time layout autotuner (DESIGN.md §Cost).

The paper's efficiency claim is O(md) aggregation compute; the repo's
four execution layouts (local/gather/a2a/blocked, plus the elastic
masked mode) realise it with very different constant factors per leaf.
This module makes those constants *predictable*:

  Cost            composable (FLOPs, HBM bytes, collective bytes/hops)
                  record — the FlopCount idiom: every term is built per
                  (statistic | column rule | collective) and summed, so
                  a new aggregator or layout composes existing terms
                  instead of re-deriving a closed form.
  HardwareProfile turns a Cost into seconds.  ``tpu_v5e`` is the
                  roofline lower bound (max of compute/memory/wire
                  terms, constants from ``launch.roofline``) and drives
                  the autotuner; ``cpu`` models the forced-host-device
                  bench rig (serialized devices, additive terms) and
                  anchors the drift gate.
  plan_layouts    the trace-time autotuner: scores gather vs a2a per
                  leaf under ``tpu_v5e`` and returns a LayoutPlan —
                  big leaves → a2a (wire ~2·v·b beats the gather's
                  m·v·b), tiny leaves → gather (fewer/cheaper hops),
                  stat-free mean → the replicated pmean fast path.
                  Purely shape-driven: deterministic for fixed shapes.
  predict_contract per-case collective counts/bytes of the lint matrix,
                  leaf-by-leaf from the same per-leaf formulas the
                  planner scores — pinned EXACTLY against the
                  ``CollectiveContract`` extraction (BENCH_contracts).
  validate_rows   the prediction→measurement loop: measured
                  BENCH_agg.json rows must be explainable by the
                  analytic feature shapes within ``factor`` (2×) per
                  row after a per-group scale calibration — CI fails
                  on any row that drifts beyond it (check_bench.py,
                  launch/autotune.py).

Everything here is importable without devices: contract prediction
uses the *static* sharding resolver (``models.params._spec_for`` takes
a plain ``{axis: size}`` dict), and planning needs only leaf numels.
jax-touching imports stay inside functions.
"""
from __future__ import annotations

import logging
import math
from dataclasses import dataclass, field, replace
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

from ..launch.hlo_stats import dtype_bytes
from ..launch import roofline

log = logging.getLogger("repro.costmodel")

# --------------------------------------------------------------------------
# Cost — the composable record
# --------------------------------------------------------------------------

COLL_KINDS = ("all_gather", "all_reduce", "all_to_all", "reduce_scatter",
              "ppermute")


def _merge(a: Mapping, b: Mapping, k: float = 1.0) -> dict:
    out = dict(a)
    for key, v in b.items():
        out[key] = out.get(key, 0.0) + v * k
    return {key: v for key, v in out.items() if v}


@dataclass(frozen=True)
class Cost:
    """One additive cost term (or a sum of them).

    ``coll_bytes``/``coll_count`` are keyed by collective kind (the
    :mod:`.contract` vocabulary) — bytes are per-step payload totals,
    counts are executions per step, exactly the quantities
    ``CollectiveContract.summary()`` records.
    """
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Mapping[str, float] = field(default_factory=dict)
    coll_count: Mapping[str, float] = field(default_factory=dict)

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.flops + other.flops,
                    self.hbm_bytes + other.hbm_bytes,
                    _merge(self.coll_bytes, other.coll_bytes),
                    _merge(self.coll_count, other.coll_count))

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k,
                    _merge({}, self.coll_bytes, k),
                    _merge({}, self.coll_count, k))

    __rmul__ = __mul__

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def to_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "coll_bytes": dict(self.coll_bytes),
                "coll_count": dict(self.coll_count)}


ZERO = Cost()


def compute(flops: float, hbm_bytes: float = 0.0) -> Cost:
    return Cost(flops=flops, hbm_bytes=hbm_bytes)


def collective(kind: str, nbytes: float, count: float = 1.0) -> Cost:
    if kind not in COLL_KINDS:
        raise ValueError(f"unknown collective kind {kind!r}")
    return Cost(coll_bytes={kind: nbytes * count},
                coll_count={kind: count})


# --------------------------------------------------------------------------
# hardware profiles
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareProfile:
    """Seconds from a Cost.  ``additive=False`` is the roofline lower
    bound (overlap assumed: max of the three terms); ``additive=True``
    models a rig with no overlap (the CPU bench host).  ``serialize``
    multiplies compute by the device count sharing one chip (forced
    host devices)."""
    name: str
    flops: float
    hbm_bw: float
    coll_bw: float
    coll_lat_s: float = 1e-6       # per collective execution
    a2a_lat_factor: float = 2.0    # all_to_all hop premium vs all_gather
    dispatch_s: float = 0.0        # per-step fixed overhead
    additive: bool = False
    serialize: int = 1

    def time_s(self, cost: Cost) -> float:
        compute_s = cost.flops * self.serialize / self.flops
        memory_s = cost.hbm_bytes / self.hbm_bw
        lat = 0.0
        for kind, n in cost.coll_count.items():
            f = self.a2a_lat_factor if kind == "all_to_all" else 1.0
            lat += n * self.coll_lat_s * f
        coll_s = cost.total_coll_bytes / self.coll_bw + lat
        if self.additive:
            return self.dispatch_s + compute_s + memory_s + coll_s
        return self.dispatch_s + max(compute_s, memory_s, coll_s)


PROFILES = {
    # the planning profile: deterministic, from launch.roofline's
    # TPU v5e constants — layout choices never depend on the backend
    # the trace happens to run on
    "tpu_v5e": HardwareProfile(
        name="tpu_v5e", flops=roofline.PEAK_FLOPS, hbm_bw=roofline.HBM_BW,
        coll_bw=roofline.LINK_BW),
    # the bench rig: 8 forced host devices share one CPU, so per-device
    # compute serializes and nothing overlaps
    "cpu": HardwareProfile(
        name="cpu", flops=5e10, hbm_bw=2e10, coll_bw=2e10,
        coll_lat_s=2e-5, dispatch_s=3e-5, additive=True, serialize=8),
}


def get_profile(profile) -> HardwareProfile:
    if isinstance(profile, HardwareProfile):
        return profile
    try:
        return PROFILES[profile]
    except KeyError:
        raise KeyError(f"unknown profile {profile!r}; "
                       f"known: {sorted(PROFILES)}") from None


# --------------------------------------------------------------------------
# analytic compute features — the stat/select contract as FLOP shapes
# --------------------------------------------------------------------------
#
# Everything the executors do per leaf decomposes into four flop
# classes over the worker matrix [m, d]:
#
#   lin     streaming passes (means, L1 norms, masking, the weighted
#           combine): c·m·d with a small per-term constant
#   gram    pairwise distances (krum-family): 2·m²·d
#   sort    the stage-vectorized bitonic stack (kernels.ref
#           sorted_worker_stack, all elastic sorts): m·log²₂(m)·d
#   refuse  the row-list bitonic network (kernels.ref
#           sorted_worker_rows): XLA re-fuses the compare-exchange cone
#           once PER CONSUMED ROW, so reading r rows costs r·cone(m)·d
#           with cone(m) ≈ m·log²₂(m)/2 — the honest model of the
#           measured trimmed-mean cliff, not a smooth idealization.
#
# Per-statistic term table (stat names are the engine's leaf_stats
# contract); column rules and selects add their own terms below.
#           (lin·m·d, gram·m²·d, needs a coordinate-median sort pass)
STAT_TERMS = {
    "scores": (6.0, 0.0, True),
    "l1": (2.0, 0.0, False),
    "gram": (0.0, 2.0, False),
    "d2med": (2.0, 0.0, True),
}
_COMBINE_LIN = 2.0          # Σ wᵢgᵢ / Σ wᵢ

# kernels.ref._TRIM_STACK_MIN_M: below this the trimmed-mean column
# rule reads its kept rows off the row-list network (refuse class);
# at/above it the stage-vectorized stack takes over.  Pinned against
# the kernel constant in tests/test_costmodel.py.
TRIM_STACK_MIN_M = 33
# XLA:CPU stops re-fusing a consumed row's compare-exchange cone once
# the network exceeds ~this many compare ops; the materialized
# intermediates then stream through memory (the refuse_b split below).
CONE_FUSE_OPS = 512.0
# working-set threshold for the bench host's last-level cache
L3_BYTES = 16e6

FEATURE_NAMES = ("const", "fast", "lin", "sort", "gram",
                 "refuse_s", "refuse_b", "lin_sp", "sort_sp", "gram_sp",
                 "wire")


def _cone(m: int) -> float:
    lg = math.log2(max(m, 2))
    return m * lg * lg / 2.0


def _trim_rows(m: int, trim_frac: float = 0.25) -> int:
    k = int(trim_frac * m)
    if 2 * k >= m:
        k = (m - 1) // 2
    return m - 2 * k


def _spec_terms(aggregator: str):
    """(stats frozenset, column kind | None) for an aggregator —
    resolved from the live engine registry so new registrations are
    covered, with the shipped column rules recognised by name."""
    from ..core.engine import get_spec
    spec = get_spec(aggregator)
    column = None
    if spec.column is not None:
        column = ("trimmed" if "trimmed" in getattr(
            spec.column, "__name__", "") else "median")
    return spec.stats, column


def compute_features(aggregator: str, m: int, d: float,
                     elastic: bool = False) -> dict:
    """Flop-class magnitudes of one local aggregation over [m, d]."""
    stats, column = _spec_terms(aggregator)
    lg = math.log2(max(m, 2))
    stack = m * lg * lg * d
    lin = sort = refuse = gram = 0.0
    needs_median = False
    for s in stats:
        lw, gw, med = STAT_TERMS.get(s, (2.0, 0.0, False))
        lin += lw * m * d
        gram += gw * m * m * d
        needs_median = needs_median or med
    if column == "median":
        needs_median = True
    elif column == "trimmed":
        rr = _trim_rows(m)
        lin += rr * d
        if elastic or m >= TRIM_STACK_MIN_M:
            sort += stack
        else:
            refuse += rr * _cone(m) * d
    if needs_median:
        if elastic:
            sort += stack
        else:
            refuse += 2 * _cone(m) * d      # two rows bracket the median
    if column is None:
        lin += _COMBINE_LIN * m * d         # weighted combine
    if elastic:
        lin += 2.0 * m * d                  # validity masking passes
    big_cone = (m * lg * lg) > CONE_FUSE_OPS
    spill = (m * d * 4.0) > L3_BYTES
    return {
        "const": 1.0, "fast": 0.0,
        "lin": lin, "sort": sort, "gram": gram,
        "refuse_s": 0.0 if big_cone else refuse,
        "refuse_b": refuse if big_cone else 0.0,
        "lin_sp": lin if spill else 0.0,
        "sort_sp": sort if spill else 0.0,
        "gram_sp": gram if spill else 0.0,
        "wire": 0.0,
    }


def local_cost(aggregator: str, m: int, d: float, dtype="f32",
               elastic: bool = False) -> Cost:
    """Collapsed Cost of one local aggregation (flops = Σ flop classes,
    hbm = the G matrix streamed once per pass-equivalent)."""
    f = compute_features(aggregator, m, d, elastic)
    flops = f["lin"] + f["sort"] + f["gram"] + f["refuse_s"] + f["refuse_b"]
    return compute(flops, hbm_bytes=m * d * dtype_bytes(dtype))


def row_features(row: Mapping) -> dict:
    """Feature vector of one BENCH_agg.json timing row.

    Distributed rows (gather/a2a/blocked on the forced-host-device rig)
    serialize: the gather layout computes stats on the FULL [m, d]
    matrix on every device (×m work), a2a/blocked on 1/m chunks (×1),
    and the wire feature carries the serialized payload totals."""
    agg, layout, m, d = (row["aggregator"], row["layout"],
                         int(row["m"]), float(row["d"]))
    if layout in ("local", "elastic"):
        return compute_features(agg, m, d, elastic=layout == "elastic")
    fast = agg == "mean" and layout in ("gather", "a2a")
    rep = m if layout == "gather" else 1.0
    f = compute_features(agg, m, d)
    out = {k: 0.0 for k in FEATURE_NAMES}
    out["const"] = 1.0
    out["fast"] = 1.0 if fast else 0.0
    if not fast:
        for k in ("lin", "sort", "gram", "refuse_s", "refuse_b"):
            out[k] = f[k] * rep
    if fast:
        out["wire"] = m * d * 4
    elif layout == "gather":
        out["wire"] = m * m * d * 4 + m * d * 4
    else:
        out["wire"] = 2 * m * d * 4
    return out


# --------------------------------------------------------------------------
# per-leaf collective formulas — engine.aggregate_sharded's conventions
# --------------------------------------------------------------------------

def leaf_collectives(aggregator: str, layout: str, m: int, numel: int,
                     dtype="f32", fast_paths: bool = True) -> Cost:
    """Collective Cost of ONE leaf (per-worker shard numel ``numel``)
    through one layout — counts and payload bytes exactly as the
    engine emits them (pinned against the CollectiveContract
    extraction by predict_contract / tests):

      gather  one all_gather [m, v] for the stats/column view; select
              specs add the gather-free f32 psum combine.
      a2a     one all_to_all + one tiled all_gather over the m-padded
              flattened leaf; the stats psum is accounted separately
              (:func:`stats_psum_cost` — once per step, not per leaf).
      mean    fast path: one pmean (all_reduce) per leaf, nothing else.
    """
    b = dtype_bytes(dtype)
    stats, column = _spec_terms(aggregator)
    mean_fast = aggregator == "mean" and fast_paths
    if layout == "local":
        return ZERO
    if mean_fast and layout in ("gather", "a2a"):
        return collective("all_reduce", numel * b)
    padded = m * math.ceil(numel / m)
    if layout == "a2a":
        return (collective("all_to_all", padded * b)
                + collective("all_gather", padded * b))
    if layout == "gather":
        cost = ZERO
        if stats or column is not None:
            cost += collective("all_gather", m * numel * b)
        if column is None:                  # select spec: psum combine
            cost += collective("all_reduce", numel * 4)
        return cost
    raise ValueError(f"unknown layout {layout!r}")


def stats_psum_cost(aggregator: str, m: int) -> Cost:
    """The once-per-step stats psum: one all_reduce operand per
    statistic, [m] f32 each ([m, m] for gram)."""
    stats, _ = _spec_terms(aggregator)
    cost = ZERO
    for s in sorted(stats):
        elems = m * m if s == "gram" else m
        cost += collective("all_reduce", elems * 4)
    return cost


def leaf_cost(aggregator: str, layout: str, m: int, numel: int,
              dtype="f32", fast_paths: bool = True,
              elastic: bool = False) -> Cost:
    """Full per-leaf Cost (compute + collectives) of one layout.

    gather computes stats on the full gathered [m, v] on every worker;
    a2a on this worker's [m, ⌈v/m⌉] chunk — the m× compute asymmetry
    that, with the m× wire asymmetry, drives the autotuner."""
    cols = numel if layout == "gather" else math.ceil(numel / m)
    comp = local_cost(aggregator, m, cols, dtype, elastic)
    return comp + leaf_collectives(aggregator, layout, m, numel,
                                   dtype, fast_paths)


# --------------------------------------------------------------------------
# the trace-time autotuner
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LayoutPlan:
    """Per-leaf layout decisions for one aggregation region."""
    aggregator: str
    m: int
    layouts: tuple                  # "gather" | "a2a" per leaf
    fast_path: bool = False         # replicated pmean (stat-free mean)
    profile: str = "tpu_v5e"

    def describe(self) -> str:
        n = len(self.layouts)
        n_a2a = sum(1 for l in self.layouts if l == "a2a")
        head = (f"plan[{self.aggregator} m={self.m} {self.profile}] "
                f"{n} leaves: {n_a2a} a2a / {n - n_a2a} gather")
        return head + (" (mean fast path)" if self.fast_path else "")


def plan_layouts(aggregator: str, m: int,
                 leaves: Sequence, profile="tpu_v5e",
                 fast_paths: bool = True,
                 elastic: bool = False) -> LayoutPlan:
    """Score gather vs a2a per leaf and pick the cheaper one.

    ``leaves``: (numel, dtype) pairs of the PER-WORKER leaf shards (what
    the engine sees inside the manual region).  Deterministic: depends
    only on the shapes, m, the aggregator contract and the (fixed)
    planning profile — never on the runtime backend."""
    prof = get_profile(profile)
    if aggregator == "mean" and fast_paths and not elastic:
        return LayoutPlan(aggregator, m, ("gather",) * len(leaves),
                          fast_path=True, profile=prof.name)
    n = max(len(leaves), 1)
    share = stats_psum_cost(aggregator, m) * (1.0 / n)
    picks = []
    for numel, dtype in leaves:
        t = {}
        for layout in ("gather", "a2a"):
            cost = leaf_cost(aggregator, layout, m, int(numel), dtype,
                             fast_paths, elastic)
            if layout == "a2a":
                cost += share
            t[layout] = prof.time_s(cost)
        # strict inequality: ties (e.g. zero-size leaves) stay on the
        # paper-faithful gather
        picks.append("a2a" if t["a2a"] < t["gather"] else "gather")
    return LayoutPlan(aggregator, m, tuple(picks), profile=prof.name)


def predict_time(aggregator: str, layout: str, m: int,
                 leaves: Sequence, profile="tpu_v5e",
                 fast_paths: bool = True, elastic: bool = False) -> float:
    """Predicted step-time lower bound (seconds) of one uniform layout
    over a leaf list — the roofline combination of the summed Cost."""
    prof = get_profile(profile)
    total = ZERO
    needs_psum = False
    for numel, dtype in leaves:
        total += leaf_cost(aggregator, layout, m, int(numel), dtype,
                           fast_paths, elastic)
        needs_psum = needs_psum or layout == "a2a"
    if needs_psum and not (aggregator == "mean" and fast_paths):
        total += stats_psum_cost(aggregator, m)
    return prof.time_s(total)


# --------------------------------------------------------------------------
# contract prediction — the 49-case lint matrix, leaf by leaf
# --------------------------------------------------------------------------

def _lint_leaves(mesh_name: str):
    """Static leaf inventory of the lint arch on one lint mesh:
    [(bucket key, full numel, per-worker numel (global scope), stack
    trips)] — no Mesh, no devices: sharding comes from the static
    resolver (``params._spec_for`` on a plain {axis: size} dict)."""
    import jax

    from ..configs import ARCHS
    from ..models import params as PM
    from ..models import transformer as TF
    from .matrix import LINT_ARCH, LINT_MESHES

    cfg = ARCHS[LINT_ARCH].reduced()
    defs = TF.param_defs(cfg)
    shape, axes = LINT_MESHES[mesh_name]
    mesh_shape = dict(zip(axes, shape))
    model_n = mesh_shape.get("model", 1)
    is_def = lambda x: isinstance(x, PM.ParamDef)
    out = []
    for key, sub in defs.items():
        for d in jax.tree.leaves(sub, is_leaf=is_def):
            numel = 1
            for s in d.shape:
                numel *= int(s)
            spec = PM._spec_for(d, mesh_shape, (), True)
            sharded = any("model" in ((e,) if isinstance(e, str) else
                                      tuple(e or ()))
                          for e in spec)
            v_local = numel // model_n if sharded else numel
            trips = int(d.shape[0]) if key.startswith("seg_") else 1
            out.append((key, numel, v_local, trips))
    return out


def predict_contract(aggregator: str, layout: str, mesh_name: str) -> dict:
    """Predicted per-step collective counts/bytes of one lint-matrix
    case — same roll-up shape as ``CollectiveContract.summary()``
    (communication kinds only; axis_index is not communication).
    Pinned exactly against BENCH_contracts.json by the cost-model test
    suite and check_bench.py."""
    from .matrix import LINT_MESHES, N_DEVICES

    if layout == "local":
        return {"counts": {}, "bytes": {}, "collective_bytes": 0.0}
    shape, axes = LINT_MESHES[mesh_name]
    mesh_shape = dict(zip(axes, shape))
    leaves = _lint_leaves(mesh_name)
    total = ZERO
    if layout == "blocked":
        # every axis is a worker axis; per-bucket a2a aggregation runs
        # inside the backward scan — seg buckets once per layer slice,
        # the top bucket once — plus the step's three scalar psums
        # (gnorm, loss, ce)
        m = N_DEVICES
        seg_trips: dict = {}
        for key, numel, _v, trips in leaves:
            slice_numel = numel // trips
            total += leaf_collectives(aggregator, "a2a", m, slice_numel,
                                      "f32", fast_paths=False) * trips
            if key.startswith("seg_"):
                seg_trips[key] = trips
        bucket_execs = sum(seg_trips.values()) + 1
        total += stats_psum_cost(aggregator, m) * bucket_execs
        total += collective("all_reduce", 4.0, count=3)
    else:
        m = mesh_shape["data"]
        needs_psum = False
        for _key, _numel, v_local, _trips in leaves:
            total += leaf_collectives(aggregator, layout, m, v_local, "f32")
            needs_psum = needs_psum or layout == "a2a"
        # gather on a tensor-parallel mesh closes model-sharded stat
        # partials with the same worker(+model) psum a2a needs
        if layout == "gather" and mesh_shape.get("model", 1) > 1:
            needs_psum = True
        if needs_psum and aggregator != "mean":
            total += stats_psum_cost(aggregator, m)
    counts = {k: v for k, v in sorted(total.coll_count.items())}
    nbytes = {k: round(v, 1) for k, v in sorted(total.coll_bytes.items())}
    return {"counts": counts, "bytes": nbytes,
            "collective_bytes": round(total.total_coll_bytes, 1)}


def validate_contracts(contracts: dict) -> list:
    """Exact predicted-vs-extracted comparison over every case of a
    BENCH_contracts.json payload.  Returns error strings."""
    errors = []
    for c in contracts.get("cases", []):
        case = f"{c['aggregator']}/{c['layout']}/{c['mesh']}"
        try:
            want = predict_contract(c["aggregator"], c["layout"], c["mesh"])
        except Exception as e:            # unknown aggregator etc.
            errors.append(f"{case}: prediction failed ({e})")
            continue
        got_counts = {k: v for k, v in c["counts"].items()
                      if k != "axis_index"}
        if got_counts != want["counts"]:
            errors.append(f"{case}: collective counts {got_counts} != "
                          f"predicted {want['counts']}")
        for k, v in want["bytes"].items():
            gv = c["bytes"].get(k)
            if gv is None or abs(gv - v) > 0.5:
                errors.append(f"{case}: {k} bytes {gv} != predicted {v}")
        extra = set(c["bytes"]) - set(want["bytes"])
        if extra:
            errors.append(f"{case}: unpredicted collective bytes for "
                          f"{sorted(extra)}")
        if abs(c["collective_bytes"] - want["collective_bytes"]) > 0.5:
            errors.append(f"{case}: collective_bytes "
                          f"{c['collective_bytes']} != predicted "
                          f"{want['collective_bytes']}")
    return errors


# --------------------------------------------------------------------------
# the drift gate — measured rows vs the analytic shapes
# --------------------------------------------------------------------------

def _group_key(row: Mapping):
    layout = row["layout"]
    if layout in ("local", "elastic"):
        return (row["aggregator"], layout)
    return ("*", layout)        # distributed rows: cross-aggregator fit


def fit_group(rows: Sequence[Mapping]):
    """Calibrate one group: nonnegative least squares of measured times
    over the analytic features, minimizing RELATIVE error, then a
    geometric-mean scale.  Returns (per-row predictions, drift array)
    where drift[i] = measured / predicted (scale-normalized)."""
    t = np.array([float(r["us_per_call"]) for r in rows])
    F = np.array([[row_features(r)[n] for n in FEATURE_NAMES]
                  for r in rows])
    keep = [j for j in range(F.shape[1]) if F[:, j].any()]
    # relative least squares with a nonnegativity projection: clip
    # negative weights and refit on the surviving columns until stable
    # (at most n_features rounds — each drops at least one column)
    for _ in range(len(keep)):
        X = F[:, keep] / t[:, None]
        w, *_ = np.linalg.lstsq(X, np.ones(len(t)), rcond=None)
        if np.all(w >= 0.0) or len(keep) == 1:
            break
        keep = [j for j, wj in zip(keep, w) if wj > 0]
        if not keep:
            keep = [0]
    w = np.maximum(w, 0.0)
    pred = np.maximum(F[:, keep] @ w, 1e-9)
    scale = math.exp(float(np.mean(np.log(t / pred))))
    pred = pred * scale
    return pred, t / pred


def validate_rows(bench: dict, factor: float = 2.0) -> list:
    """The drift gate: every measured BENCH_agg.json row must sit
    within ``factor`` (either way) of the analytic prediction after
    per-group calibration.  A row that drifts means the measurement
    changed shape — a perf regression (or a broken bench) — and CI
    fails instead of silently re-anchoring."""
    errors = []
    groups: dict = {}
    for r in bench.get("rows", []):
        if not isinstance(r, dict):
            continue
        us = r.get("us_per_call")
        if not (isinstance(us, (int, float)) and math.isfinite(us)
                and us > 0):
            continue        # schema checks reject these separately
        groups.setdefault(_group_key(r), []).append(r)
    for key in sorted(groups):
        rows = groups[key]
        try:
            pred, drift = fit_group(rows)
        except Exception as e:
            errors.append(f"group {key}: cost-model fit failed ({e})")
            continue
        for r, p, dd in zip(rows, pred, drift):
            if dd > factor or dd < 1.0 / factor:
                errors.append(
                    f"{r['aggregator']}/{r['layout']} m={r['m']} "
                    f"d={r['d']}: measured {r['us_per_call']:.1f}us "
                    f"drifts {max(dd, 1 / dd):.2f}x from the cost-model "
                    f"prediction {p:.1f}us (> {factor:g}x gate) — "
                    f"re-profile or fix the regression")
    return errors


def validate_pick(bench: dict, tol: float = 0.25) -> list:
    """The autotune acceptance check: for every (aggregator × mesh
    family) with measured distributed rows, the layout the planner
    picks must be within ``tol`` of the best measured layout's row."""
    errors = []
    by_case: dict = {}
    for r in bench.get("rows", []):
        if isinstance(r, dict) and r.get("layout") in ("gather", "a2a",
                                                       "blocked"):
            by_case.setdefault(
                (r["aggregator"], int(r["m"]), int(r["d"])), {})[
                    r["layout"]] = float(r["us_per_call"])
    for (agg, m, d), times in sorted(by_case.items()):
        plan = plan_layouts(agg, m, [(d, "f32")])
        chosen = "a2a" if "a2a" in plan.layouts else "gather"
        if plan.fast_path:
            # fast-path rows measure identically through either layout;
            # take the better of the two measured entries
            chosen = min(("gather", "a2a"), key=lambda l:
                         times.get(l, float("inf")))
        if chosen not in times:
            errors.append(f"{agg} m={m} d={d}: no measured row for the "
                          f"planned layout {chosen!r}")
            continue
        best = min(times.values())
        if times[chosen] > best * (1.0 + tol):
            worst = times[chosen] / best
            errors.append(
                f"{agg} m={m} d={d}: planned layout {chosen!r} measures "
                f"{times[chosen]:.1f}us, {worst:.2f}x the best layout "
                f"({best:.1f}us) — beyond the {tol:.0%} acceptance band")
    return errors
