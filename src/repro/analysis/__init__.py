"""Static analysis of what a compiled step structurally does.

Two IR walkers produce one :class:`~repro.analysis.contract.
CollectiveContract` shape — ``jaxpr`` (trace-time, axis names + manual
context) and ``hlo`` (lowered text via ``launch.hlo_stats``) — checked
by the declarative rule registry in :mod:`.rules` over the full
(aggregator × layout × mesh) matrix in :mod:`.matrix`.  CLI:
``python -m repro.launch.lint``.  DESIGN.md §Analysis.

:mod:`.costmodel` adds the analytic side: per-(aggregator × layout ×
mesh × leaf-shape) cost estimates, the trace-time layout autotuner
behind ``agg_layout="auto"``, and the predicted-vs-measured drift gate.
CLI: ``python -m repro.launch.autotune``.  DESIGN.md §Cost.
"""
from .contract import (COMM_KINDS, KINDS, CollectiveContract, CollectiveOp,
                       merge)
from .jaxpr import extract, trace
from .rules import (LintRule, RuleContext, Violation, get_rule,
                    register, registered, run_rules)
from .costmodel import (Cost, HardwareProfile, LayoutPlan, get_profile,
                        plan_layouts, predict_contract, predict_time)
from . import costmodel, hlo, jaxpr, matrix, rules  # noqa: F401

__all__ = [
    "COMM_KINDS", "KINDS", "CollectiveContract", "CollectiveOp", "merge",
    "extract", "trace", "LintRule", "RuleContext", "Violation",
    "get_rule", "register", "registered", "run_rules",
    "Cost", "HardwareProfile", "LayoutPlan", "get_profile",
    "plan_layouts", "predict_contract", "predict_time",
    "costmodel", "hlo", "jaxpr", "matrix", "rules",
]
