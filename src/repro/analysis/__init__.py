"""Static analysis of what a compiled step structurally does.

Two IR walkers produce one :class:`~repro.analysis.contract.
CollectiveContract` shape — ``jaxpr`` (trace-time, axis names + manual
context) and ``hlo`` (lowered text via ``launch.hlo_stats``) — checked
by the declarative rule registry in :mod:`.rules` over the full
(aggregator × layout × mesh) matrix in :mod:`.matrix`.  CLI:
``python -m repro.launch.lint``.  DESIGN.md §Analysis.
"""
from .contract import (COMM_KINDS, KINDS, CollectiveContract, CollectiveOp,
                       merge)
from .jaxpr import extract, trace
from .rules import (LintRule, RuleContext, Violation, get_rule,
                    register, registered, run_rules)
from . import hlo, jaxpr, matrix, rules  # noqa: F401

__all__ = [
    "COMM_KINDS", "KINDS", "CollectiveContract", "CollectiveOp", "merge",
    "extract", "trace", "LintRule", "RuleContext", "Violation",
    "get_rule", "register", "registered", "run_rules",
    "hlo", "jaxpr", "matrix", "rules",
]
