"""The (aggregator × layout × mesh) lint matrix: trace every registered
aggregator through every execution path and check the contracts.

Pure tracing — ``jax.make_jaxpr`` on ShapeDtypeStructs, nothing is
executed or compiled, so the whole matrix is cheap on CPU.  Scope
follows layout: ``gather``/``a2a`` run the global-scope step,
``blocked`` the blocked/FSDP step, ``local`` the single-host dense
executor (no mesh).  The driver CLI is ``python -m repro.launch.lint``
(which forces the 8 host devices the meshes below need BEFORE jax
imports); CI runs it per mesh family via ``REPRO_TEST_MESHES``.

:func:`seeded_cases` builds the deliberately-broken toys (double
gather, bf16 stats psum, partial-manual gather, worker-matrix gather,
tiny budget, unmasked elastic stats psum) that prove each shipped rule
actually fires — ``lint --selftest`` and tests/test_analysis.py run
them.
"""
from __future__ import annotations

import math
from functools import partial

from . import jaxpr as ajaxpr
from .rules import RuleContext, run_rules

# mesh families mirror tests/meshes.py, sized to the 8 host devices the
# lint CLI forces: flat = worker-only, dm = data×model (tensor-parallel
# 'model' axis in the global scope, folded into the workers in blocked)
LINT_MESHES = {
    "flat": ((8,), ("data",)),
    "dm": ((4, 2), ("data", "model")),
}
N_DEVICES = 8
LINT_ARCH = "qwen3-0.6b"    # smallest arch; traced in reduced() form
LAYOUTS = ("local", "gather", "a2a", "blocked")
LOCAL_D = 4096              # dense-executor G columns


def make_lint_mesh(name: str):
    from ..launch.mesh import make_mesh
    shape, axes = LINT_MESHES[name]
    return make_mesh(shape, axes)


def mesh_names():
    """Active mesh families (REPRO_TEST_MESHES comma-list filters,
    exactly like tests/meshes.py)."""
    import os
    want = os.environ.get("REPRO_TEST_MESHES", "")
    names = [n.strip() for n in want.split(",") if n.strip()] \
        or list(LINT_MESHES)
    unknown = [n for n in names if n not in LINT_MESHES]
    if unknown:
        raise ValueError(f"REPRO_TEST_MESHES: unknown meshes {unknown}; "
                         f"known: {sorted(LINT_MESHES)}")
    return names


def case_key(aggregator: str, layout: str, mesh_name: str) -> str:
    return f"{aggregator}/{layout}/{mesh_name}"


def all_cases(meshes=None):
    """Yield (aggregator, layout, mesh_name) over the full matrix.
    ``local`` has no mesh (mesh_name "none")."""
    from ..core import engine
    meshes = list(meshes if meshes is not None else LINT_MESHES)
    for agg in engine.registered():
        yield agg, "local", "none"
        for mesh_name in meshes:
            for layout in ("gather", "a2a", "blocked"):
                yield agg, layout, mesh_name


def lint_train_config(aggregator: str, layout: str):
    from ..configs import ARCHS, ByzantineConfig, TrainConfig
    scope = "blocked" if layout == "blocked" else "global"
    return TrainConfig(
        model=ARCHS[LINT_ARCH].reduced(),
        byzantine=ByzantineConfig(aggregator=aggregator),
        optimizer="sgd",
        agg_scope=scope,
        agg_layout="a2a" if layout == "blocked" else layout)


def _step_structs(tcfg, bundle, mesh):
    """(params, opt_state, batch, step_idx, key) ShapeDtypeStructs for
    one make_jaxpr of the train step — shapes only, nothing allocated."""
    import jax
    import jax.numpy as jnp

    from ..launch.mesh import n_workers
    from ..launch.specs import key_struct
    from ..models import params as PM
    from ..models import transformer as TF

    cfg = tcfg.model
    pdtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, pdtype),
                     TF.param_defs(cfg),
                     is_leaf=lambda x: isinstance(x, PM.ParamDef))
    if tcfg.optimizer == "sgd":
        o = ()
    else:
        f32 = jnp.float32
        mk = lambda: jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, f32), TF.param_defs(cfg),
            is_leaf=lambda x: isinstance(x, PM.ParamDef))
        o = mk() if tcfg.optimizer == "momentum" else {"m": mk(), "v": mk()}
    mw = n_workers(mesh, bundle.scope)
    batch = {"tokens": jax.ShapeDtypeStruct((mw, 1, 16), jnp.int32)}
    if cfg.n_prefix_tokens:
        batch["prefix_embed"] = jax.ShapeDtypeStruct(
            (mw, 1, cfg.n_prefix_tokens, cfg.d_model), jnp.bfloat16)
    return p, o, batch, jax.ShapeDtypeStruct((), jnp.int32), key_struct()


def _blocked_gather_ceiling(cfg, m: int) -> int:
    """Largest legal all_gather payload (numel) of the blocked step: one
    m-padded BUCKET leaf — seg_* buckets hand the barrier per-layer
    slices (the scan consumes the leading stack dim), the top bucket
    full leaves.  FSDP streaming gathers a full leaf (≤ the padded
    size); ``engine.unchunk`` re-assembly gathers exactly the padded
    size.  Anything larger is an m×-sized worker matrix."""
    import jax

    from ..models import params as PM
    from ..models import transformer as TF
    ceiling = m   # selection-token / scalar traffic floor
    for key, leaves in TF.param_defs(cfg).items():
        for d in jax.tree.leaves(
                leaves, is_leaf=lambda x: isinstance(x, PM.ParamDef)):
            n = 1
            for s in d.shape:
                n *= int(s)
            if key.startswith("seg_"):
                n //= int(d.shape[0])       # scan slice
            ceiling = max(ceiling, m * math.ceil(n / m))
    return ceiling


def trace_case(aggregator: str, layout: str, mesh_name: str, mesh=None,
               budgets=None, budget_factor: float = 2.0):
    """Trace one matrix case -> (CollectiveContract, RuleContext)."""
    import jax

    from ..configs import ByzantineConfig
    from ..core import engine, threat

    spec = engine.get_spec(aggregator)
    budget = (budgets or {}).get(case_key(aggregator, layout, mesh_name))

    if layout == "local":
        m = N_DEVICES
        G = jax.ShapeDtypeStruct((m, LOCAL_D), jax.numpy.float32)
        cfg = ByzantineConfig(aggregator=aggregator)
        contract = ajaxpr.trace(
            partial(engine.aggregate_local, cfg=cfg), G,
            meta={"ir": "jaxpr"})
        ctx = RuleContext(case=case_key(aggregator, layout, mesh_name),
                          aggregator=aggregator, layout=layout,
                          scope="none", mesh_name="none", m=m, n_leaves=1,
                          spec=spec, budget=budget,
                          budget_factor=budget_factor)
        return contract, ctx

    from ..launch.mesh import n_workers
    from ..training.step import build_train_step

    if mesh is None:
        mesh = make_lint_mesh(mesh_name)
    tcfg = lint_train_config(aggregator, layout)
    bundle = build_train_step(tcfg, mesh, jit=False)
    structs = _step_structs(tcfg, bundle, mesh)
    contract = ajaxpr.extract(jax.make_jaxpr(bundle.step_fn)(*structs),
                              meta={"ir": "jaxpr"})
    m = n_workers(mesh, bundle.scope)
    n_leaves = len(jax.tree.leaves(structs[0]))
    ceiling = (_blocked_gather_ceiling(tcfg.model, m)
               if layout == "blocked" else 0)
    from ..launch.mesh import worker_axes as mesh_worker_axes
    ctx = RuleContext(
        case=case_key(aggregator, layout, mesh_name),
        aggregator=aggregator, layout=layout, scope=bundle.scope,
        mesh_name=mesh_name, m=m, n_leaves=n_leaves,
        max_gather_numel=ceiling, spec=spec,
        attack_counts=threat.inject_collectives(tcfg.byzantine, n_leaves, m),
        budget=budget, budget_factor=budget_factor,
        elastic=tcfg.byzantine.elastic,
        worker_axes=tuple(mesh_worker_axes(mesh, bundle.scope)))
    return contract, ctx


def run_matrix(meshes=None, budgets=None, budget_factor: float = 2.0,
               progress=None):
    """Trace + lint the whole matrix.

    Returns ``(records, violations)``: one record per case (case info +
    ``CollectiveContract.summary()`` — the BENCH_contracts.json body)
    and the flat list of rule Violations."""
    meshes = list(meshes if meshes is not None else mesh_names())
    mesh_cache = {n: make_lint_mesh(n) for n in meshes}
    records, violations = [], []
    for agg, layout, mesh_name in all_cases(meshes):
        contract, ctx = trace_case(agg, layout, mesh_name,
                                   mesh=mesh_cache.get(mesh_name),
                                   budgets=budgets,
                                   budget_factor=budget_factor)
        vs = run_rules(contract, ctx)
        violations.extend(vs)
        records.append({"aggregator": agg, "layout": layout,
                        "mesh": mesh_name, "scope": ctx.scope,
                        "m": ctx.m, "n_leaves": ctx.n_leaves,
                        **contract.summary()})
        if progress:
            progress(ctx.case, contract, vs)
    return records, violations


# ---------------------------------------------------------------------------
# seeded violations — proof each shipped rule fires (lint --selftest)
# ---------------------------------------------------------------------------

def seeded_cases(meshes=("flat",)):
    """[(expected_rule_name, contract, ctx)] of deliberately-broken
    toys, one per shipped rule."""
    import jax
    import jax.numpy as jnp

    from ..compat import P, shard_map
    from ..configs import ByzantineConfig
    from ..core import engine

    flat = make_lint_mesh("flat")
    m = N_DEVICES
    spec = engine.get_spec("brsgd")
    bcfg = ByzantineConfig(aggregator="brsgd")
    cases = []

    def toy_ctx(layout, **kw):
        return RuleContext(case=f"seeded/{layout}", aggregator="brsgd",
                           layout=layout, scope="global", mesh_name="flat",
                           m=m, n_leaves=1, spec=spec, **kw)

    # 1. the seed's bug class: gather each leaf for stats, then gather
    #    it AGAIN for the combine — one-gather-per-leaf must fire
    @partial(shard_map, mesh=flat, in_specs=(P("data"),), out_specs=P())
    def double_gather(g):
        g = g.reshape(g.shape[1:])
        G = engine.gather_leaf(g, ("data",), m)
        stats = engine.leaf_stats(G, ("l1", "scores"), m)
        w, _, denom = engine.resolve_select(spec, stats, bcfg, m)
        G2 = engine.gather_leaf(g, ("data",), m)        # BUG: re-gather
        return jnp.tensordot(w, G2.reshape(m, -1), axes=1) / denom

    g = jax.ShapeDtypeStruct((m, 24), jnp.float32)
    cases.append(("one-gather-per-leaf",
                  ajaxpr.trace(double_gather, g, meta={"ir": "jaxpr"}),
                  toy_ctx("gather")))

    # 2. bf16 stats partials psum — psum-stats-dtype must fire
    @partial(shard_map, mesh=flat, in_specs=(P("data"),), out_specs=P())
    def bf16_stats(x):
        part = jnp.sum(x.astype(jnp.bfloat16), axis=0)      # [m] partial
        return jax.lax.psum(part, ("data",)).astype(jnp.float32)

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    cases.append(("psum-stats-dtype",
                  ajaxpr.trace(bf16_stats, x, meta={"ir": "jaxpr"}),
                  toy_ctx("gather")))

    # 3. the PR-5 crash class: a worker all_gather inside a
    #    PARTIAL-manual region (dm mesh, 'model' left auto) — trace-time
    #    only; lowering this dies in XLA SPMD with IsManualSubgroup
    if "dm" in meshes:
        dm = make_lint_mesh("dm")

        @partial(shard_map, mesh=dm, in_specs=(P("data"),), out_specs=P(),
                 axis_names=("data",))
        def partial_manual(g):
            return jnp.sum(jax.lax.all_gather(g, ("data",)))

        g = jax.ShapeDtypeStruct((4, 8), jnp.float32)
        cases.append(("no-collective-over-auto-axis",
                      ajaxpr.trace(partial_manual, g, meta={"ir": "jaxpr"}),
                      toy_ctx("gather")))

    # 4. a gather-layout fallback inside a blocked step: all_gather of
    #    [m, *leaf] — no-worker-gather-in-blocked-bwd must fire
    @partial(shard_map, mesh=flat, in_specs=(P("data"),), out_specs=P())
    def worker_matrix_gather(g):
        g = g.reshape(g.shape[1:])
        G = jax.lax.all_gather(g, ("data",))                # [m, *leaf]
        return jnp.sum(G.astype(jnp.float32))

    g = jax.ShapeDtypeStruct((m, 6), jnp.float32)
    ceiling = m * math.ceil(6 / m)
    cases.append(("no-worker-gather-in-blocked-bwd",
                  ajaxpr.trace(worker_matrix_gather, g, meta={"ir": "jaxpr"}),
                  toy_ctx("blocked", max_gather_numel=ceiling)))

    # 5. a 1-byte envelope — bytes-budget must fire on any real traffic
    cases.append(("bytes-budget", cases[0][1],
                  toy_ctx("gather", budget={"collective_bytes": 1.0})))

    # 6. an elastic round whose worker stats psum drops the validity
    #    slot: masked partials close over the workers WITHOUT
    #    stats["valid"] riding the eqn — masked-psum-validity must fire
    @partial(shard_map, mesh=flat, in_specs=(P("data"), P()), out_specs=P())
    def unmasked_elastic_psum(g, vf):
        g = g.reshape(g.shape[1:])
        Gv, _ = engine.a2a_chunk(g, ("data",), m)
        stats = engine.leaf_stats(Gv, ("scores", "l1"), m,
                                  use_pallas=False, valid=vf)
        stats = jax.lax.psum(stats, ("data",))      # BUG: no "valid" slot
        w, _, denom = engine.resolve_select(
            spec, {**stats, "valid": vf}, bcfg, m)
        wi = w[jax.lax.axis_index(("data",))]
        return jax.lax.psum(wi * jnp.sum(Gv), ("data",)) / denom

    g6 = jax.ShapeDtypeStruct((m, 24), jnp.float32)
    vf6 = jax.ShapeDtypeStruct((m,), jnp.float32)
    cases.append(("masked-psum-validity",
                  ajaxpr.trace(unmasked_elastic_psum, g6, vf6,
                               meta={"ir": "jaxpr"}),
                  toy_ctx("a2a", elastic=True, worker_axes=("data",))))

    return cases


def run_selftest(meshes=("flat", "dm")) -> list:
    """Check every seeded toy trips exactly its rule, with the op-level
    (file/collective) detail attached.  Returns failure strings."""
    failures = []
    for rule, contract, ctx in seeded_cases(meshes):
        vs = run_rules(contract, ctx, rules=[rule])
        if not vs:
            failures.append(f"{rule}: seeded violation NOT detected "
                            f"({ctx.case})")
            continue
        if rule != "bytes-budget" and not any(v.op for v in vs):
            failures.append(f"{rule}: violation carries no collective "
                            f"detail ({ctx.case})")
        if rule in ("one-gather-per-leaf",
                    "no-collective-over-auto-axis") and not any(
                        v.op and v.op.source for v in vs):
            failures.append(f"{rule}: violation carries no source "
                            f"location ({ctx.case})")
    return failures
