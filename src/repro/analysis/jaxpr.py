"""Trace-time contract extraction: walk a closed jaxpr and record every
collective with its axes, payload and manual-axis context.

This is the ONE jaxpr-walking implementation in the repo — the ad-hoc
walkers the pin tests in tests/test_engine.py (gather-count) and
tests/test_blocked.py (barrier no-fallback) used to carry are migrated
onto :func:`extract` / :func:`trace`.

The walk recurses through every higher-order primitive generically
(``pjit``, ``scan``, ``while``, ``cond`` branches, ``custom_vjp`` /
``custom_jvp`` call jaxprs, ``remat``): any equation parameter that is
a Jaxpr/ClosedJaxpr (or a tuple/list of them) is entered.  Two
primitives get special handling:

  * ``shard_map`` — establishes the manual-axis context.  Its ``auto``
    parameter names the mesh axes that stay under GSPMD inside the
    region; everything else is manual.  Collectives recorded inside
    carry that context, which is what the ``no-collective-over-auto-
    axis`` rule (the PR-5 XLA SPMD crash class) reads.
  * ``scan``/``while`` — multiply the trip count into every op of the
    body (``scan`` declares ``length``; ``while`` trips are unknown at
    trace time and are counted once, noted in ``notes``).
"""
from __future__ import annotations

import numpy as np

from .contract import KIND_FROM_PRIM, CollectiveContract, CollectiveOp

_LOOP_PRIMS = {"scan"}


def _source(eqn) -> str:
    try:
        from jax._src import source_info_util
        return str(source_info_util.summarize(eqn.source_info))
    except Exception:
        return ""


def _axis_names(params) -> tuple:
    """Mesh axis names a collective runs over (``axes``/``axis_name``
    param; positional vmap axes — ints — are dropped)."""
    raw = params.get("axes", params.get("axis_name", ()))
    if raw is None:
        return ()
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _var_payload(v):
    """(shape, dtype str, bytes) of one jaxpr atom, 0 for non-numeric
    avals (tokens, extended dtypes without a byte width)."""
    aval = v.aval
    shape = tuple(getattr(aval, "shape", ()))
    dt = getattr(aval, "dtype", None)
    try:
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
    except Exception:
        return shape, str(dt), 0.0
    return shape, str(np.dtype(dt)), float(nbytes)


def _sub_jaxprs(val):
    """Yield raw Jaxprs inside one eqn param value."""
    if hasattr(val, "jaxpr"):           # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):          # raw Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub_jaxprs(v)


class _Walk:
    def __init__(self):
        self.ops = []
        self.notes = {}
        self.n_eqns = 0     # global collective-eqn counter -> op.group

    def walk(self, jaxpr, mult=1.0, manual=(), auto=(), in_sm=False):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name

            if name == "shard_map":
                mesh = eqn.params.get("mesh")
                auto_axes = tuple(sorted(eqn.params.get("auto", ()) or ()))
                names = tuple(getattr(mesh, "axis_names", ()))
                man = tuple(a for a in names if a not in auto_axes)
                for sub in _sub_jaxprs(eqn.params.get("jaxpr")):
                    self.walk(sub, mult, man, auto_axes, True)
                continue

            kind = KIND_FROM_PRIM.get(name)
            if kind is not None:
                axes = _axis_names(eqn.params)
                # one record per payload operand: a psum of a stats dict
                # binds several arrays in one eqn, and rules reason
                # per-array (shape/dtype).  ``group`` ties the operands
                # of ONE eqn back together — the masked-psum-validity
                # rule reasons about a whole stats psum at once.
                gid = self.n_eqns
                self.n_eqns += 1
                outs = eqn.outvars if kind != "reduce_scatter" \
                    else eqn.invars
                for v in (outs or eqn.outvars):
                    shape, dt, nbytes = _var_payload(v)
                    self.ops.append(CollectiveOp(
                        kind=kind, axes=axes, shape=shape, dtype=dt,
                        bytes=nbytes, count=mult, manual_axes=manual,
                        auto_axes=auto, in_shard_map=in_sm,
                        source=_source(eqn), ir="jaxpr", group=gid))
                continue

            sub_mult = mult
            if name in _LOOP_PRIMS:
                sub_mult = mult * float(eqn.params.get("length", 1))
            elif name == "while":
                self.notes["unknown_trip_whiles"] = \
                    self.notes.get("unknown_trip_whiles", 0) + 1
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    self.walk(sub, sub_mult, manual, auto, in_sm)


def extract(closed_jaxpr, meta=None) -> CollectiveContract:
    """Contract of a (closed) jaxpr — pjit/scan/custom_vjp/shard_map
    regions are entered recursively, trip counts multiplied through."""
    w = _Walk()
    jx = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    w.walk(jx)
    return CollectiveContract(ops=tuple(w.ops), meta=dict(meta or {}),
                              notes=w.notes)


def trace(fn, *args, meta=None, **kwargs) -> CollectiveContract:
    """``jax.make_jaxpr`` + :func:`extract` in one call.  ``args`` may
    be ShapeDtypeStructs — nothing is executed."""
    import jax
    return extract(jax.make_jaxpr(fn)(*args, **kwargs), meta=meta)
