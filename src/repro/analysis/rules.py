"""Declarative lint rules over CollectiveContracts.

Mirrors the AggregatorSpec / AttackSpec idiom: a :class:`LintRule`
declares WHAT must hold for a contract, never HOW the contract was
obtained — the same rule checks a trace-time jaxpr contract and a
lowered-HLO contract (``ir`` narrows a rule to the IRs that carry the
facts it reads; axis names and shapes exist only on the jaxpr side).

Adding a rule is one :func:`register` call with a ``check(contract,
ctx) -> iterable[(message, op | None)]`` function; it is then applied
by :func:`run_rules`, by the full-matrix driver (``analysis.matrix`` /
``python -m repro.launch.lint``) and by the CI ``lint-contracts`` job.
DESIGN.md §Analysis has the add-a-rule recipe.

Shipped rules
-------------
no-worker-gather-in-blocked-bwd
    The blocked/FSDP step never all_gathers an m×-sized worker matrix:
    every gather payload is at most one m-padded bucket leaf (FSDP
    param streaming or ``engine.unchunk`` re-assembly).  A gather-layout
    fallback inside the barrier backward would exceed that immediately.
one-gather-per-leaf
    Transient-collective counts match ``engine.expected_collectives``
    exactly: gather layout gathers each leaf ONCE (zero for the
    stat-free mean), a2a moves one all_to_all + one unchunk all_gather
    per leaf, local is collective-free.
no-collective-over-auto-axis
    The PR-5 XLA SPMD crash class, caught at trace time: gather-type
    collectives (and axis_index) must live in FULL-manual regions —
    a shard_map with leftover auto axes only supports reduce-type
    collectives — and no op may name an axis outside the region's
    manual set.
psum-stats-dtype
    [m]/[m,m] statistic partials (engine stats, attack knowledge
    moments ride the same contract) are reduced in float32 — a bf16
    stats psum silently halves the accumulator mantissa across workers.
bytes-budget
    Per-step collective payload bytes stay within ``budget_factor`` (2×
    either way) of the envelope recorded in BENCH_contracts.json, so
    communication regressions fail CI instead of shipping silently.
masked-psum-validity
    Elastic rounds only (DESIGN.md §Elastic): every worker-axis stats
    psum must carry the [m] validity-mask slot the engine rides on the
    same eqn (``stats["valid"]``) — a stats psum without it means some
    path folded dropped workers' garbage into the selection.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .contract import CollectiveContract, CollectiveOp

# collectives (+ axis_index) that XLA can only lower inside FULL-manual
# shard_map regions — partial-manual subgroups support reduce-type
# collectives only (DESIGN.md §Mesh)
MANUAL_ONLY_KINDS = ("all_gather", "all_to_all", "ppermute", "axis_index")


@dataclass(frozen=True)
class RuleContext:
    """Everything a rule may know about the case behind a contract."""
    case: str = ""                  # display id, e.g. "brsgd/gather/flat"
    aggregator: str = ""
    layout: str = "local"           # local | gather | a2a | blocked
    scope: str = "none"             # none | global | blocked
    mesh_name: str = "none"         # none | flat | dm
    m: int = 1                      # worker count of the case
    n_leaves: int = 0               # gradient leaves the step aggregates
    max_gather_numel: int = 0       # largest legal gather payload (numel)
    spec: object = None             # engine.AggregatorSpec | None
    attack_counts: Optional[dict] = None   # threat.inject_collectives(...)
    fast_paths: bool = True
    budget: Optional[dict] = None   # BENCH_contracts.json case entry
    budget_factor: float = 2.0
    elastic: bool = False           # elastic quorum round (§Elastic)
    worker_axes: tuple = ()         # mesh axes indexing the workers
    plan: Optional[tuple] = None    # per-leaf layouts when layout="auto"


@dataclass(frozen=True)
class Violation:
    rule: str
    case: str
    message: str
    op: Optional[CollectiveOp] = None

    def format(self) -> str:
        head = f"[{self.rule}] {self.case}: {self.message}"
        return head + (f"\n    {self.op.describe()}" if self.op else "")


@dataclass(frozen=True)
class LintRule:
    """One declarative check over a contract."""
    name: str
    doc: str
    check: Callable                 # (contract, ctx) -> [(msg, op|None)]
    ir: frozenset = frozenset({"jaxpr", "hlo"})
    applies: Callable = field(default=lambda ctx: True)


_REGISTRY: dict[str, LintRule] = {}


def register(rule: LintRule) -> LintRule:
    _REGISTRY[rule.name] = rule
    return rule


def get_rule(name: str) -> LintRule:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown lint rule {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered() -> tuple:
    return tuple(sorted(_REGISTRY))


def run_rules(contract: CollectiveContract, ctx: RuleContext,
              rules=None) -> list:
    """Apply every applicable rule; returns a list of Violations."""
    ir = contract.meta.get("ir") or next(
        (op.ir for op in contract.ops), "jaxpr")
    out = []
    for name in (rules if rules is not None else registered()):
        rule = get_rule(name) if isinstance(name, str) else name
        if ir not in rule.ir or not rule.applies(ctx):
            continue
        for msg, op in rule.check(contract, ctx):
            out.append(Violation(rule.name, ctx.case, msg, op))
    return out


# ---------------------------------------------------------------------------
# shipped rules
# ---------------------------------------------------------------------------

def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _check_blocked_gathers(contract, ctx):
    # HLO ops carry no shapes: bound their payload in bytes, assuming
    # the widest wire dtype the barrier moves (f32)
    max_bytes = ctx.max_gather_numel * 4
    for op in contract.of_kind("all_gather"):
        if op.ir == "jaxpr":
            sz = _numel(op.shape)
            if sz > ctx.max_gather_numel:
                yield (f"all_gather payload {sz} elements exceeds one "
                       f"m-padded bucket leaf ({ctx.max_gather_numel}): "
                       f"an m×-sized worker-matrix gather (gather-layout "
                       f"fallback) leaked into the blocked step", op)
        elif op.bytes > max_bytes:
            yield (f"all_gather payload {op.bytes:.0f} B exceeds one "
                   f"m-padded f32 bucket leaf ({max_bytes} B)", op)


register(LintRule(
    "no-worker-gather-in-blocked-bwd",
    "blocked step gathers at most one m-padded bucket leaf at a time",
    _check_blocked_gathers,
    applies=lambda ctx: ctx.layout == "blocked" and ctx.max_gather_numel > 0,
))


def _check_gather_counts(contract, ctx):
    from ..core.engine import expected_collectives
    want = expected_collectives(ctx.spec, ctx.layout, ctx.n_leaves,
                                ctx.fast_paths, plan=ctx.plan)
    for kind, n in want.items():
        got = contract.count(kind)
        if got != n:
            ops = contract.of_kind(kind)
            yield (f"expected {n} {kind} per step "
                   f"({ctx.n_leaves} leaves, {ctx.layout} layout), "
                   f"traced {got:g}", ops[0] if ops else None)


register(LintRule(
    "one-gather-per-leaf",
    "transient collective counts match engine.expected_collectives",
    _check_gather_counts,
    ir=frozenset({"jaxpr"}),
    applies=lambda ctx: (ctx.layout in ("local", "gather", "a2a")
                         or (ctx.layout == "auto" and ctx.plan is not None))
                        and ctx.spec is not None,
))


def _check_auto_axis(contract, ctx):
    for op in contract.ops:
        if not op.in_shard_map:
            continue
        if op.kind in MANUAL_ONLY_KINDS and op.auto_axes:
            yield (f"{op.kind} inside a PARTIAL-manual region (auto axes "
                   f"{list(op.auto_axes)}): XLA SPMD only lowers "
                   f"reduce-type collectives in manual subgroups — run "
                   f"this region full-manual (DESIGN.md §Mesh)", op)
        bad = set(op.axes) - set(op.manual_axes)
        if bad:
            yield (f"{op.kind} over non-manual axes {sorted(bad)} "
                   f"(manual set: {list(op.manual_axes)})", op)


register(LintRule(
    "no-collective-over-auto-axis",
    "gather-type collectives only in full-manual regions, over manual axes",
    _check_auto_axis,
    ir=frozenset({"jaxpr"}),
))


def _check_stats_dtype(contract, ctx):
    stat_shapes = {(ctx.m,), (ctx.m, ctx.m)}
    for op in contract.of_kind("all_reduce"):
        if (tuple(op.shape) in stat_shapes and op.dtype.startswith(
                ("float", "bfloat")) and op.dtype != "float32"):
            yield (f"[m]-statistic partials reduced in {op.dtype}; "
                   f"cross-worker stat psums must accumulate in float32",
                   op)


register(LintRule(
    "psum-stats-dtype",
    "[m]/[m,m] statistic partials psum in float32",
    _check_stats_dtype,
    ir=frozenset({"jaxpr"}),
    applies=lambda ctx: (ctx.spec is None or bool(ctx.spec.stats)
                         or bool((ctx.attack_counts or {}).get("all_reduce"))),
))


def _check_bytes_budget(contract, ctx):
    total = contract.total_bytes()
    env = float(ctx.budget.get("collective_bytes", 0.0))
    f = ctx.budget_factor
    hi, lo = max(total, env), min(total, env)
    if hi > lo * f and hi > 0:
        yield (f"per-step collective payload {total:.0f} B drifted "
               f">{f:g}× from the recorded envelope {env:.0f} B "
               f"(BENCH_contracts.json) — regenerate with "
               f"`python -m repro.launch.lint --record` if intended",
               None)


register(LintRule(
    "bytes-budget",
    "per-step collective bytes within the recorded envelope",
    _check_bytes_budget,
    applies=lambda ctx: ctx.budget is not None,
))


def _check_masked_psum(contract, ctx):
    stat_shapes = {(ctx.m,), (ctx.m, ctx.m)}
    groups: dict = {}
    for op in contract.of_kind("all_reduce"):
        if op.group < 0 or not (set(op.axes) & set(ctx.worker_axes)):
            continue
        groups.setdefault(op.group, []).append(op)
    n_stats = len(ctx.spec.stats) if ctx.spec is not None else 0
    for gid in sorted(groups):
        ops = groups[gid]
        if not all(tuple(op.shape) in stat_shapes for op in ops):
            continue        # leaf/combine traffic, not a stats psum
        # a stats psum binds ≥2 stat-shaped arrays in one eqn (stats +
        # validity slot); a lone [m,m] Gram psum is also a stats psum
        # (krum-family single-stat specs)
        is_stats = (len(ops) >= 2
                    or all(tuple(op.shape) == (ctx.m, ctx.m) for op in ops))
        if not is_stats:
            continue
        if len(ops) <= n_stats:
            yield (f"worker-axis stats psum binds {len(ops)} operand(s) "
                   f"for a {n_stats}-statistic spec: the [m] validity "
                   f"mask (stats['valid']) must ride the same psum in an "
                   f"elastic round, or dropped workers' partials poison "
                   f"the selection (DESIGN.md §Elastic)", ops[0])


register(LintRule(
    "masked-psum-validity",
    "elastic-round worker stats psums carry the [m] validity-mask slot",
    _check_masked_psum,
    ir=frozenset({"jaxpr"}),
    applies=lambda ctx: ctx.elastic and bool(ctx.worker_axes),
))
