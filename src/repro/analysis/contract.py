"""CollectiveContract — what a compiled step structurally DOES on the
wire, as data.

A contract is the list of collective operations a traced (jaxpr) or
lowered (HLO) step executes per call: which collective, over which mesh
axes, on which shapes/dtypes, how many bytes, how many times (loop trip
counts folded in), and the manual-vs-auto axis context it runs in.
Communication becomes a first-class, checkable quantity — the way
Alistarh et al. (1803.08917) and Yin et al. (1803.01498) account
per-round bytes analytically instead of treating them as an emergent
property of the compiler.

Two walkers produce the same shape:

  * :mod:`.jaxpr`  — trace-time, axis names + manual context available;
    catches violations before XLA ever runs (the readable-error path).
  * :mod:`.hlo`    — from lowered/compiled HLO text via
    ``launch.hlo_stats``; axis names are gone (only replica groups),
    but the contract is exactly what ships to the runtime, so the two
    must agree (tests/test_analysis.py pins it).

Declarative rules over contracts live in :mod:`.rules`; the
(aggregator × layout × mesh × scope) sweep in :mod:`.matrix`.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterable, Optional

# Canonical collective kinds, shared by both walkers.  jaxpr primitive
# and HLO opcode names both map onto these (see KIND_FROM_PRIM /
# KIND_FROM_HLO); ``axis_index`` is not communication but is tracked
# because it has the same manual-axes lowering constraint the PR-5
# crash class is about.
KINDS = ("all_gather", "all_reduce", "all_to_all", "reduce_scatter",
         "ppermute", "axis_index")
COMM_KINDS = tuple(k for k in KINDS if k != "axis_index")

KIND_FROM_PRIM = {
    "all_gather": "all_gather",
    "all_gather_invariant": "all_gather",
    "psum": "all_reduce",
    "psum2": "all_reduce",
    "pmin": "all_reduce",
    "pmax": "all_reduce",
    "all_to_all": "all_to_all",
    "psum_scatter": "reduce_scatter",
    "reduce_scatter": "reduce_scatter",
    "ppermute": "ppermute",
    "pshuffle": "ppermute",
    "axis_index": "axis_index",
}

KIND_FROM_HLO = {
    "all-gather": "all_gather",
    "all-reduce": "all_reduce",
    "all-to-all": "all_to_all",
    "reduce-scatter": "reduce_scatter",
    "collective-permute": "ppermute",
}


@dataclass(frozen=True)
class CollectiveOp:
    """One collective operation of a step.

    ``bytes`` is the PAYLOAD moved by one execution (result bytes;
    operand bytes for reduce_scatter) — a layout-comparable quantity,
    deliberately NOT the ring-algorithm wire volume
    (``launch.hlo_stats`` keeps that for the roofline).  ``count`` is
    how many times the op executes per step (enclosing scan/while trip
    counts multiplied through); per-step traffic is ``bytes * count``.
    """
    kind: str                     # one of KINDS
    axes: tuple = ()              # mesh axis names (jaxpr walker only)
    shape: tuple = ()             # payload shape (jaxpr walker only)
    dtype: str = ""               # payload dtype / HLO type string
    bytes: float = 0.0            # payload bytes per execution
    count: float = 1.0            # executions per step (trip counts)
    manual_axes: tuple = ()       # manual axes of the enclosing region
    auto_axes: tuple = ()         # auto axes of the enclosing shard_map
    in_shard_map: bool = False
    source: str = ""              # "file:line (fn)" when known
    ir: str = "jaxpr"             # "jaxpr" | "hlo"
    group: int = -1               # eqn id: operands of ONE collective eqn
                                  # share a group (jaxpr walker; -1 = n/a)

    @property
    def total_bytes(self) -> float:
        return self.bytes * self.count

    def describe(self) -> str:
        loc = f" @ {self.source}" if self.source else ""
        ax = f" over {'×'.join(self.axes)}" if self.axes else ""
        sh = (f" {self.dtype}{list(self.shape)}" if self.shape
              else (f" {self.dtype}" if self.dtype else ""))
        cnt = f" ×{self.count:g}" if self.count != 1 else ""
        return (f"{self.kind}{sh}{ax} ({self.bytes:.0f} B{cnt}, "
                f"manual={','.join(self.manual_axes) or '-'}"
                + (f", AUTO={','.join(self.auto_axes)}" if self.auto_axes
                   else "") + f"){loc}")


@dataclass(frozen=True)
class CollectiveContract:
    """The per-step collective behaviour of one traced/lowered step."""
    ops: tuple = ()               # tuple[CollectiveOp, ...]
    meta: dict = field(default_factory=dict)
    notes: dict = field(default_factory=dict)   # e.g. unknown_trip_whiles

    def with_meta(self, **kw) -> "CollectiveContract":
        return replace(self, meta={**self.meta, **kw})

    def of_kind(self, *kinds: str) -> tuple:
        return tuple(op for op in self.ops if op.kind in kinds)

    def count(self, kind: str) -> float:
        return sum(op.count for op in self.ops if op.kind == kind)

    def total_bytes(self, kind: Optional[str] = None) -> float:
        """Per-step payload traffic, axis_index excluded."""
        kinds = (kind,) if kind else COMM_KINDS
        return sum(op.total_bytes for op in self.ops if op.kind in kinds)

    def summary(self) -> dict:
        """JSON-able roll-up (the BENCH_contracts.json case body)."""
        counts = {k: self.count(k) for k in KINDS if self.count(k)}
        nbytes = {k: round(self.total_bytes(k), 1) for k in COMM_KINDS
                  if self.count(k)}
        return {"counts": counts, "bytes": nbytes,
                "collective_bytes": round(self.total_bytes(), 1)}


def merge(contracts: Iterable[CollectiveContract]) -> CollectiveContract:
    ops, notes = [], {}
    for c in contracts:
        ops.extend(c.ops)
        for k, v in c.notes.items():
            notes[k] = notes.get(k, 0) + v
    return CollectiveContract(ops=tuple(ops), notes=notes)
