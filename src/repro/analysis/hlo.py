"""Lowered-side contract extraction: the same CollectiveContract shape
as :mod:`.jaxpr`, read from HLO text via ``launch.hlo_stats``.

Works on BOTH HLO flavours the launch layer produces:

  * unoptimized pre-SPMD HLO (``jax.stages.Lowered.compiler_ir('hlo')``
    — what ``dryrun --lower-only`` persists): only the manual-region
    collectives exist (GSPMD has not partitioned the auto regions yet),
    so the contract matches the jaxpr walker's op-for-op — the
    agreement pin in tests/test_analysis.py.
  * compiled post-SPMD HLO (``compiled.as_text()`` — what the dryrun
    sweep saves): additionally contains whatever collectives GSPMD
    inserted for the auto regions, and XLA's combiner passes may have
    merged ops — counts can only shrink, per-kind payload bytes are
    preserved.

Axis names and manual context do not survive lowering, so HLO-side ops
carry replica-group size in ``axes``-free form and the rules that need
axis context are jaxpr-only (``LintRule.ir``).
"""
from __future__ import annotations

from .contract import KIND_FROM_HLO, CollectiveContract, CollectiveOp


def extract(hlo_text: str, meta=None) -> CollectiveContract:
    """Contract of an HLO module (text form, either flavour)."""
    from ..launch.hlo_stats import module_stats
    stats = module_stats(hlo_text)
    ops = []
    for rec in stats["collective_ops"]:
        kind = KIND_FROM_HLO.get(rec["op"])
        if kind is None:
            continue
        ops.append(CollectiveOp(
            kind=kind, axes=(), shape=(), dtype=rec["type"],
            bytes=float(rec["bytes"]), count=float(rec["count"]),
            source=f"group_size={rec['group']}", ir="hlo"))
    notes = {}
    if stats.get("unknown_trip_whiles"):
        notes["unknown_trip_whiles"] = stats["unknown_trip_whiles"]
    return CollectiveContract(ops=tuple(ops), meta=dict(meta or {}),
                              notes=notes)


def lower_to_hlo_text(lowered) -> str:
    """Unoptimized HLO text of a ``jax.stages.Lowered`` — the
    pre-execution path (``dryrun --lower-only``): no compile needed,
    manual-region collectives already present."""
    try:
        return lowered.compiler_ir(dialect="hlo").as_hlo_text()
    except Exception:
        # very old/new jax: fall back to whatever text exists (StableHLO
        # — collective extraction then yields an empty contract, which
        # callers surface rather than crash on)
        return lowered.as_text()
