from .optimizers import Optimizer, adamw, get_optimizer, momentum, sgd
