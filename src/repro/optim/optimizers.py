"""Minimal optimizer library (optax-free, pure pytrees).

States are plain pytrees matching the parameter tree, so they shard
exactly like parameters (the dry-run gives them the same
PartitionSpecs).  All accumulators are float32 regardless of parameter
dtype; updates are cast back.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable      # params -> state
    update: Callable    # (grads, state, params, step) -> (new_params, new_state)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads
    n = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)


def sgd(lr: float, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        grads = clip_by_global_norm(grads, grad_clip)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32))
            .astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        grads = clip_by_global_norm(grads, grad_clip)
        new_m = jax.tree.map(
            lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
            params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, grad_clip: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        grads = clip_by_global_norm(grads, grad_clip)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (u + weight_decay * pf)
            return pf.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def get_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "sgd":
        return sgd(cfg.lr, cfg.grad_clip)
    if cfg.optimizer == "momentum":
        return momentum(cfg.lr, cfg.momentum, cfg.grad_clip)
    if cfg.optimizer == "adamw":
        return adamw(cfg.lr, weight_decay=cfg.weight_decay,
                     grad_clip=cfg.grad_clip)
    raise ValueError(cfg.optimizer)
