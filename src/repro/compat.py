"""JAX API compatibility shims.

The repo targets the modern shard_map surface (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.sharding.AxisType`` meshes,
``jax.lax.axis_size``) but must also run on the 0.4.x line, where the
same features live under different names:

  new (>= 0.5)                         old (0.4.x)
  ------------------------------------ -----------------------------------
  jax.shard_map(..., axis_names=M,     jax.experimental.shard_map.shard_map(
               check_vma=...)              ..., auto=mesh_axes - M,
                                           check_rep=...)
  jax.make_mesh(..., axis_types=Auto)  jax.make_mesh(...)  (no axis types)
  jax.lax.axis_size(axes)              jax.lax.psum(1, axes)

Everything that touches these APIs imports from here, never from jax
directly, so the version split lives in exactly one file.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P  # noqa: F401  (re-export)

__all__ = ["P", "axis_size", "make_mesh", "shard_map"]


def make_mesh(shape, axes):
    """Mesh with Auto axis types where the concept exists."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        return jax.make_mesh(shape, axes)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma,
                          auto=auto)


def axis_size(axes) -> int:
    """Product of the named mesh axis sizes (inside shard_map)."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axes))
    # psum of the python constant 1 resolves statically to the axis size
    return int(jax.lax.psum(1, axes))
