"""Blocked (streaming) BrSGD: robust aggregation inside the backward
scan, with FSDP parameter gathering fused into the same barrier.

For >20B models the full per-worker gradient matrix G (m × params)
cannot exist on any device set (deepseek-v2: m=32 × 472 GB).  The
paper's per-dimension math is separable across dimensions, so we run
Algorithm 2 per *bucket* (one transformer layer-stack slice, or the
top-level embed/head bucket) with bucket-local C1∩C2 selections —
aggregation happens the moment a layer's gradients are produced by the
backward scan, and only one layer's worth of cross-worker state is ever
live.

The mechanism is a ``jax.custom_vjp`` barrier applied to each scanned
layer slice (see ``transformer.forward(param_hook=...)``):

  forward :  p_full = all_gather(p_shard) over the worker axes
             (FSDP streaming — params live sharded over workers)
  backward:  g_full (this worker's layer gradient)
             -> optional Byzantine attack injection
             -> all_to_all workers×dims transpose along the FSDP dim
             -> per-dim stats, per-bucket selection, masked mean
             -> returns the aggregated gradient's local FSDP shard

so the optimizer consumes already-aggregated, already-sharded grads.
Deviation from the paper (documented in DESIGN.md): selections are
per-bucket instead of global.  tests/test_blocked.py shows the
robustness behaviour matches the global rule under all four attacks.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import axis_size
from ..configs.base import ByzantineConfig
from ..models.params import shard_hint
from .engine import brsgd_select
from .distributed import inject_attack


def _fsdp_dim(spec: P, axes) -> int | None:
    """Index of the dim sharded over the worker axes in ``spec``."""
    want = tuple(axes) if len(axes) > 1 else axes[0]
    for i, e in enumerate(spec):
        if e == want or (isinstance(e, tuple) and set(e) == set(axes)):
            return i
    return None


def _gather_leaf(x, dim: int | None, axes):
    if dim is None:
        return x
    return jax.lax.all_gather(x, axes, axis=dim, tiled=True)


def _a2a_worker_view(g, dim: int, m: int):
    """[..., d, ...] -> [..., m, d/m, ...] with dim ``dim`` (size m)
    indexing workers after the all_to_all."""
    s = g.shape
    g = g.reshape(s[:dim] + (m, s[dim] // m) + s[dim + 1:])
    return g


def _bucket_aggregate(g_full, specs, bcfg: ByzantineConfig, axes):
    """Aggregate one bucket of per-worker gradients.

    g_full: pytree of this worker's gradients (full dims).
    Returns the pytree of aggregated gradients in FSDP layout (leaves
    with an FSDP dim come back as the local shard).
    """
    m = axis_size(axes)
    leaves, tdef = jax.tree.flatten(g_full)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)

    views = []          # (kind, worker-view array, fsdp dim)
    sc_part = jnp.zeros((m,), jnp.float32)
    l1_part = jnp.zeros((m,), jnp.float32)
    sc_repl = jnp.zeros((m,), jnp.float32)
    l1_repl = jnp.zeros((m,), jnp.float32)

    for g, spec in zip(leaves, spec_leaves):
        k = _fsdp_dim(spec, axes)
        # §Perf: collectives move the gradient in ITS OWN dtype (bf16 for
        # bf16 params — half the wire bytes); statistics upcast locally.
        # NOTE: no whole-tensor f32 upcast — XLA hoists a post-collective
        # convert to BEFORE the collective, doubling wire bytes.  Stats
        # use f32 ACCUMULATION over the bf16 values instead (decision
        # statistics are invariant to bf16 rounding of the operands).
        if k is not None and g.shape[k] % m == 0 and g.shape[k] >= m:
            x = _a2a_worker_view(g, k, m)
            # keep the tensor-parallel ('model' etc.) sharding of the
            # OTHER dims through the worker re-shard — without the hint
            # XLA un-shards the auto axes around the manual all_to_all
            # (a 16x all-gather of expert-sharded MoE grads)
            vspec = []
            for i, e in enumerate(spec):
                ent = None if (e == tuple(axes) or e in axes
                               or (isinstance(e, tuple)
                                   and set(e) & set(axes))) else e
                vspec.extend([None, None] if i == k else [ent])
            x = shard_hint(x, P(*vspec))
            Gw = jax.lax.all_to_all(x, axes, split_axis=k, concat_axis=k,
                                    tiled=False)
            # stop XLA hoisting the stats' f32 upcasts BEFORE the
            # collective (that would double the wire bytes)
            Gw = jax.lax.optimization_barrier(Gw)
            Gw = shard_hint(Gw, P(*vspec))
            red = tuple(i for i in range(Gw.ndim) if i != k)
            mean_c = jnp.mean(Gw, axis=k, keepdims=True, dtype=jnp.float32)
            above = Gw.astype(jnp.float32) >= mean_c
            n_above = jnp.sum(above.astype(jnp.int32), axis=k, keepdims=True)
            M = jnp.where(n_above * 2 >= m, above, ~above)
            sc_part += jnp.sum(M.astype(jnp.float32), axis=red)
            med = jnp.median(Gw, axis=k, keepdims=True)
            l1_part += jnp.sum(jnp.abs((Gw - med).astype(jnp.float32)),
                               axis=red)
            views.append(("a2a", Gw, k))
        else:
            Gw = jax.lax.all_gather(g, axes)                 # [m, ...]
            Gw = jax.lax.optimization_barrier(Gw)
            red = tuple(range(1, Gw.ndim))
            mean_c = jnp.mean(Gw, axis=0, keepdims=True, dtype=jnp.float32)
            above = Gw.astype(jnp.float32) >= mean_c
            n_above = jnp.sum(above.astype(jnp.int32), axis=0, keepdims=True)
            M = jnp.where(n_above * 2 >= m, above, ~above)
            sc_repl += jnp.sum(M.astype(jnp.float32), axis=red)
            med = jnp.median(Gw, axis=0, keepdims=True)
            l1_repl += jnp.sum(jnp.abs((Gw - med).astype(jnp.float32)),
                               axis=red)
            views.append(("gather", Gw, 0))

    scores, l1 = jax.lax.psum((sc_part, l1_part), axes)
    scores, l1 = scores + sc_repl, l1 + l1_repl

    if bcfg.aggregator == "brsgd":
        st = brsgd_select(scores, l1, bcfg.beta, bcfg.threshold)
        w = st.selected.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(w), 1.0)
    elif bcfg.aggregator == "mean":
        w = jnp.ones((m,), jnp.float32)
        denom = float(m)
    else:
        raise NotImplementedError(
            f"blocked scope supports brsgd/mean, got {bcfg.aggregator}")

    out = []
    for (kind, Gw, k), g in zip(views, leaves):
        wshape = [1] * Gw.ndim
        wshape[k] = m
        agg = jnp.sum(Gw.astype(jnp.float32) * w.reshape(wshape),
                      axis=k) / denom
        out.append(agg.astype(g.dtype))
    return jax.tree.unflatten(tdef, out)


def make_fsdp_agg_barrier(specs, bcfg: ByzantineConfig, axes, key):
    """Returns hook(p_bucket) -> gathered bucket with aggregating VJP.

    ``specs``: PartitionSpec pytree matching the bucket (one scanned
    layer slice, or the top-level bucket)."""
    axes = tuple(axes)

    @jax.custom_vjp
    def barrier(p):
        return jax.tree.map(
            lambda x, s: _gather_leaf(x, _fsdp_dim(s, axes), axes), p, specs)

    def fwd(p):
        return barrier(p), None

    def bwd(_, g_full):
        g_full = inject_attack(g_full, key, bcfg, axes)
        return (_bucket_aggregate(g_full, specs, bcfg, axes),)

    barrier.defvjp(fwd, bwd)
    return barrier
