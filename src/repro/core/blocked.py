"""Blocked (streaming) robust aggregation: every rule registered in
``core.engine`` runs inside the backward scan, with FSDP parameter
gathering fused into the same barrier.

For >20B models the full per-worker gradient matrix G (m × params)
cannot exist on any device set (deepseek-v2: m=32 × 472 GB).  Every
statistic in the engine registry is additive over disjoint dimension
ranges, so we run any registered aggregator per *bucket* (one
transformer layer-stack slice, or the top-level embed/head bucket) with
bucket-local selections — aggregation happens the moment a layer's
gradients are produced by the backward scan, and only one layer's worth
of cross-worker state is ever live.

Mesh contract (DESIGN.md §Mesh): the barrier runs inside a FULL-manual
shard_map whose manual axes are EVERY mesh axis, and the worker axes
are every mesh axis too — a tensor-parallel 'model' axis is folded
into the FSDP worker set by the step builder (XLA's partial-manual
subgroups cannot lower the all_to_all/all_gather/axis_index this
barrier needs, and per-layer TP would be re-gathered here anyway).

The mechanism is a ``jax.custom_vjp`` barrier applied to each scanned
layer slice (see ``transformer.forward(param_hook=...)``):

  forward :  p_full = all_gather(p_shard) over the worker axes
             (FSDP streaming — params live sharded over workers)
  backward:  g_full (this worker's layer gradient)
             -> optional Byzantine attack injection (``threat.inject``
                — any registered AttackSpec, incl. alie/ipm whose
                honest-statistics psum per bucket; noise key per bucket
                via :func:`bucket_key`, membership from the raw step
                key so all buckets corrupt one worker set)
             -> worker×dims all_to_all re-shard: FSDP leaves transpose
                in place along their own sharded dim; replicated and
                non-divisible (d % m != 0) leaves flatten through
                ``engine.a2a_chunk`` with zero-padding, so EVERY leaf
                stays on the 1×-memory a2a path (no all_gather
                fallback; ``engine.pad_correction`` removes the pad
                columns' score contribution)
             -> ``engine.leaf_stats`` partials (ONE fused pass per
                view — every statistic the rule declares from a single
                read, DESIGN.md §Perf), one psum, the registry
                ``select`` or ``column`` rule, weighted combine
             -> returns the aggregated gradient's local FSDP shard,
                plus the bucket's n_selected histogram on the selection
                token's cotangent

so the optimizer consumes already-aggregated, already-sharded grads and
the training loop reads truthful per-bucket selection counts.
Deviation from the paper (documented in DESIGN.md §2): selections are
per-bucket instead of global.  tests/test_blocked.py asserts
blocked-vs-global parity for every registered aggregator (single
bucket == global selection) and that the selection stays truthful
under attack.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import axis_size
from ..configs.base import ByzantineConfig
from ..models.params import shard_hint
from . import engine, threat


def _fsdp_dim(spec: P, axes) -> int | None:
    """Index of the dim sharded over the worker axes in ``spec``."""
    want = tuple(axes) if len(axes) > 1 else axes[0]
    for i, e in enumerate(spec):
        if e == want or (isinstance(e, tuple) and set(e) == set(axes)):
            return i
    return None


def _gather_leaf(x, dim: int | None, axes):
    if dim is None:
        return x
    return jax.lax.all_gather(x, axes, axis=dim, tiled=True)


def _a2a_worker_view(g, dim: int, m: int):
    """[..., d, ...] -> [..., m, d/m, ...] with dim ``dim`` (size m)
    indexing workers after the all_to_all."""
    s = g.shape
    g = g.reshape(s[:dim] + (m, s[dim] // m) + s[dim + 1:])
    return g


def _shard_view(g, spec: P, k: int, m: int, axes):
    """In-place a2a worker view of one FSDP leaf: [..., d_k, ...] ->
    f32 [..., m, d_k/m, ...] with the worker axis at ``k`` (no flatten,
    no pad — the leaf's own sharded dim is split instead)."""
    # §Perf: collectives move the gradient in ITS OWN dtype (bf16 for
    # bf16 params — half the wire bytes); statistics upcast locally
    # AFTER the optimization barrier, which stops XLA hoisting the f32
    # convert to BEFORE the collective (that would double wire bytes).
    x = _a2a_worker_view(g, k, m)
    # under the full-manual step every mesh axis is a worker axis, so
    # spec entries can only reference ``axes`` and the hint below is a
    # no-op; it is kept for spec-generality (a non-worker entry would
    # need its sharding preserved through the re-shard)
    vspec = []
    for i, e in enumerate(spec):
        ent = None if (e == tuple(axes) or e in axes
                       or (isinstance(e, tuple)
                           and set(e) & set(axes))) else e
        vspec.extend([None, None] if i == k else [ent])
    x = shard_hint(x, P(*vspec))
    Gw = jax.lax.all_to_all(x, axes, split_axis=k, concat_axis=k,
                            tiled=False)
    Gw = jax.lax.optimization_barrier(Gw)
    Gw = shard_hint(Gw, P(*vspec))
    return Gw.astype(jnp.float32)


def _bucket_aggregate(g_full, specs, bcfg: ByzantineConfig, axes,
                      valid=None):
    """Aggregate one bucket of per-worker gradients via the engine
    registry — any registered rule, not just brsgd/mean.

    g_full: pytree of this worker's gradients (full dims).
    Returns ``(aggregated pytree, SelectionState)``: leaves with an
    FSDP dim come back as the local shard, the rest replicated; the
    state carries the bucket-local selection so the training loop's
    n_selected metric is truthful.

    ``valid`` ([m] 0/1, replicated) runs the bucket elastically:
    dropped workers' gradients are zeroed on entry (exact zeros),
    statistics and the selection cover the active set, and the validity
    mask rides the bucket's stats psum as a one-hot slot — the
    ``masked-psum-validity`` lint contract (DESIGN.md §Elastic).
    """
    m = axis_size(axes)
    spec = engine.get_spec(bcfg.aggregator)
    leaves, tdef = jax.tree.flatten(g_full)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    elastic = valid is not None
    if elastic:
        vf = jnp.asarray(valid).astype(jnp.float32)
        act_i = vf[jax.lax.axis_index(axes)]
        leaves = [jnp.where(act_i > 0, g, jnp.zeros_like(g))
                  for g in leaves]

    # -- phase 0: per-leaf worker views, all on the 1×-memory a2a path.
    # ("shard", Gw, k): FSDP leaf transposed in place, worker axis k.
    # ("flat", Gc, 0):  replicated / non-divisible leaf flattened and
    #                   zero-padded through engine.a2a_chunk.
    views, total_pad = [], 0
    for g, pspec in zip(leaves, spec_leaves):
        k = _fsdp_dim(pspec, axes)
        if k is not None and g.shape[k] % m == 0 and g.shape[k] >= m:
            views.append(("shard", _shard_view(g, pspec, k, m, axes), k))
        else:
            Gc, pad = engine.a2a_chunk(g, axes, m)
            total_pad += pad
            views.append(("flat", Gc, 0))

    # -- per-dimension rules: no stats / replicated phase at all --------
    if spec.column is not None:
        colkw = {"valid": vf, "use_pallas": False} if elastic else {}
        out = []
        for (kind, Gv, k), g in zip(views, leaves):
            if kind == "shard":
                # apply the rule along the worker axis WITHOUT collapsing
                # the remaining (possibly model-sharded) dims — a
                # reshape(m, -1) would force XLA to un-shard the auto
                # axes.  The jnp reference rules are N-D over axis 0;
                # the Pallas kernels are 2-D only, so N-D views pin
                # use_pallas=False (plain XLA, still compiled).
                Gm = jnp.moveaxis(Gv, k, 0)
                kw = dict(colkw) if elastic else (
                    {"use_pallas": False} if Gm.ndim > 2 else {})
                out.append(spec.column(Gm, bcfg, m, **kw).astype(g.dtype))
            else:
                out.append(engine.unchunk(spec.column(Gv, bcfg, m, **colkw),
                                          g, axes))
        st = engine.SelectionState(
            (vf > 0) if elastic else jnp.ones((m,), bool),
            vf if elastic else jnp.ones((m,), jnp.float32))
        return jax.tree.unflatten(tdef, out), st

    # -- phase 1: per-leaf stats partials, one psum ---------------------
    stats = engine.zero_stats(spec.stats, m)
    if stats:
        for kind, Gv, k in views:
            part = engine.leaf_stats(Gv, spec.stats, m, axis=k,
                                     valid=vf if elastic else None)
            stats = {s: stats[s] + part[s] for s in stats}
        if elastic:
            # the validity mask rides the bucket's stats psum (one-hot
            # slot per active worker) — the masked-psum-validity lint
            # rule's required operand
            stats["valid"] = jax.nn.one_hot(
                jax.lax.axis_index(axes), m, dtype=jnp.float32) * act_i
        stats = jax.lax.psum(stats, axes)
        stats = engine.pad_correction(stats, total_pad,
                                      valid=vf if elastic else None)
    if elastic:
        stats = dict(stats)
        stats.setdefault("valid", vf)

    # -- phase 2: replicated selection + weighted combine ---------------
    w, st, denom = engine.resolve_select(spec, stats, bcfg, m)
    out = []
    for (kind, Gv, k), g in zip(views, leaves):
        if kind == "shard":
            agg = jnp.tensordot(w, Gv, axes=([0], [k])) / denom
            out.append(agg.astype(g.dtype))
        else:
            out.append(engine.unchunk(jnp.tensordot(w, Gv, axes=1) / denom,
                                      g, axes))
    return jax.tree.unflatten(tdef, out), st


def bucket_key(key, name: str):
    """Stable per-bucket attack NOISE key: fold the bucket's name
    (crc32, so the id survives bucket-set reordering) into the step
    key.  Without this every bucket's injected Byzantine noise is
    bit-identical — a correlated attack strictly weaker than the threat
    model (tests/test_blocked.py regression).  The barrier folds this
    INSIDE its backward (the name is static there), so the raw step key
    stays available for the step-wide membership draw — under the
    ``resample`` policy all buckets must corrupt the SAME workers."""
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def selection_token(m: int):
    """Zero token fed to the aggregation barrier alongside the params.

    Its cotangent is the one-hot histogram of the bucket's n_selected
    (length m+1, index = count), so per-bucket selection counts ride
    out of the backward scan on ordinary gradient accumulation: a
    scanned segment's token gradient is the histogram summed over its
    layers."""
    return jnp.zeros((m + 1,), jnp.float32)


def key_carrier(key):
    """PRNG key bit-cast to f32 so it can ride through the aggregation
    barrier as a differentiable-shaped primal input (cotangent: plain
    zeros).  The key CANNOT be closed over by the barrier instead: its
    bwd runs at scan-transposition time, where a closed-over tracer
    (the step key is a shard_map argument) becomes an unlowerable jaxpr
    constant.  Typed (extended-dtype) keys are unwrapped to their
    uint32 data first — the dry-run drives the step with
    ``jax.random.key`` structs."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return jax.lax.bitcast_convert_type(key, jnp.float32)


def barrier_bwd_fn(specs, bcfg: ByzantineConfig, axes, name: str = "lint",
                   elastic: bool = False):
    """Traceable stand-in for ONE barrier round trip: ``run(p_bucket,
    key, active=None) -> (agg bucket, selection histogram)``.

    The returned callable drives :func:`make_fsdp_agg_barrier` through
    ``jax.grad``, so tracing it (inside a shard_map over ``axes``)
    yields a jaxpr containing exactly the barrier's forward gathers AND
    its backward path (attack injection + bucket aggregation) — what
    ``analysis.jaxpr.extract`` and the barrier pin test
    (tests/test_blocked.py) walk for the ``no-worker-gather-in-
    blocked-bwd`` rule, without hand-rolling a vjp at every call site.
    ``p_bucket`` leaves are this device's LOCAL shards (matching
    ``specs``).  ``elastic`` builds the 5-primal elastic barrier;
    ``active`` then defaults to the all-ones mask."""
    axes = tuple(axes)
    barrier = make_fsdp_agg_barrier(specs, bcfg, axes, name,
                                    elastic=elastic)

    def run(p, key, active=None):
        m = axis_size(axes)
        keyf = key_carrier(key)

        def loss(p, tok):
            if elastic:
                act = (jnp.ones((m,), jnp.float32) if active is None
                       else jnp.asarray(active, jnp.float32))
                out = barrier(p, tok, jnp.float32(0), keyf, act)
            else:
                out = barrier(p, tok, jnp.float32(0), keyf)
            return sum(jnp.sum(x.astype(jnp.float32))
                       for x in jax.tree.leaves(out))

        agg, hist = jax.grad(loss, argnums=(0, 1))(p, selection_token(m))
        return agg, hist

    return run


def make_fsdp_agg_barrier(specs, bcfg: ByzantineConfig, axes, name: str,
                          elastic: bool = False):
    """Returns hook(p_bucket, tok, layer_idx, keyf) -> gathered bucket
    with aggregating VJP.

    ``specs``: PartitionSpec pytree matching the bucket (one scanned
    layer slice, or the top-level bucket).  ``tok`` is a
    :func:`selection_token`; its cotangent reports the bucket's real
    n_selected as a histogram (see training/step.py).  ``layer_idx``
    (f32 scalar — f32 so its cotangent is a plain zero) is the position
    inside the bucket's scan, folded into the attack noise key so the
    layers of ONE scanned segment receive different noise too — the
    per-bucket :func:`bucket_key` (folded here from the static
    ``name``) alone would repeat noise across a segment's layers, which
    all share this one hook.  ``keyf`` is the RAW step key via
    :func:`key_carrier`; the bucket/layer folds perturb only the noise,
    while byzantine MEMBERSHIP is drawn from the unfolded step key so
    every bucket corrupts one consistent worker set
    (``threat.membership_mask``).

    ``elastic`` adds a fifth primal ``activef`` ([m] f32 validity mask,
    replicated; cotangent plain zeros like ``keyf``): the bucket's
    injection and aggregation then run over the active set only.  The
    mask is a TRACED value, so one compiled step serves every active
    set up to m — the flag is static (two barrier variants) but the
    mask is not."""
    axes = tuple(axes)

    if elastic:
        @jax.custom_vjp
        def barrier(p, tok, idx, keyf, activef):
            del tok, idx, keyf, activef
            return jax.tree.map(
                lambda x, s: _gather_leaf(x, _fsdp_dim(s, axes), axes),
                p, specs)

        def fwd(p, tok, idx, keyf, activef):
            return barrier(p, tok, idx, keyf, activef), (idx, keyf, activef)

        def bwd(res, g_full):
            idx, keyf, activef = res
            key = jax.lax.bitcast_convert_type(keyf, jnp.uint32)
            key_l = jax.random.fold_in(bucket_key(key, name),
                                       idx.astype(jnp.int32))
            g_full = threat.inject(g_full, key_l, bcfg, axes,
                                   membership_key=key, active=activef)
            agg, st = _bucket_aggregate(g_full, specs, bcfg, axes,
                                        valid=activef)
            m = axis_size(axes)
            n_sel = jnp.sum(st.selected.astype(jnp.int32))
            hist = jax.nn.one_hot(n_sel, m + 1, dtype=jnp.float32)
            return (agg, hist, jnp.zeros((), jnp.float32),
                    jnp.zeros_like(keyf), jnp.zeros_like(activef))

        barrier.defvjp(fwd, bwd)
        return barrier

    @jax.custom_vjp
    def barrier(p, tok, idx, keyf):
        del tok, idx, keyf
        return jax.tree.map(
            lambda x, s: _gather_leaf(x, _fsdp_dim(s, axes), axes), p, specs)

    def fwd(p, tok, idx, keyf):
        return barrier(p, tok, idx, keyf), (idx, keyf)

    def bwd(res, g_full):
        idx, keyf = res
        key = jax.lax.bitcast_convert_type(keyf, jnp.uint32)
        key_l = jax.random.fold_in(bucket_key(key, name),
                                   idx.astype(jnp.int32))
        g_full = threat.inject(g_full, key_l, bcfg, axes,
                               membership_key=key)
        agg, st = _bucket_aggregate(g_full, specs, bcfg, axes)
        m = axis_size(axes)
        n_sel = jnp.sum(st.selected.astype(jnp.int32))
        hist = jax.nn.one_hot(n_sel, m + 1, dtype=jnp.float32)
        return agg, hist, jnp.zeros((), jnp.float32), jnp.zeros_like(keyf)

    barrier.defvjp(fwd, bwd)
    return barrier
