"""Distributed robust aggregation inside ``shard_map``: the thin
collective-facing wrapper over the layout-aware engine.

The paper's master/worker exchange maps onto TPU collectives through
:mod:`.engine`, which executes ANY registered aggregator (all seven:
mean, median, trimmed_mean, krum, multi_krum, geomedian, brsgd) in one
of two collective layouts:

  gather (paper-faithful "master collects G"):
      per leaf:  all_gather over the worker axes -> G_leaf [m, cols].
      Statistics, selection and combine run redundantly on every
      device -> m× transient memory, all_gather wire volume.

  a2a layout (beyond-paper, §Perf):
      per leaf:  flatten, zero-pad to m·⌈D/m⌉, all_to_all over the
      worker axes -> each device owns ALL workers for 1/m of the dims.
      Per-worker statistic partials finish with one psum of
      [m]-vectors ([m,m] for the Gram matrix), selection is replicated,
      and the aggregated chunk is re-assembled with a tiled all_gather.
      Transient memory 1× instead of m×; compute per device /m.

Both layouts produce the same aggregate up to f32 summation order
(identical per-dimension math; see tests/test_engine.py for the
layout-parity matrix).  What runs where is decided by the aggregator's
registry entry — per-dimension ``column`` rules (median, trimmed mean)
never need a replicated phase, ``select`` rules ship only [m]-sized
state across workers.  To add an aggregator distributed, register it
once in ``engine.py``; nothing here changes.

This module keeps the shard_map-facing API (``robust_aggregate``) and
the training-time fault injection (``inject_attack``).  Must be called
inside a shard_map whose manual axes == ``axes`` (the worker axes); the
'model' mesh axis stays auto, so leaves may be arbitrarily
tensor-sharded — the math here never notices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import axis_size
from ..configs.base import ByzantineConfig
from . import engine


def worker_index(axes):
    return jax.lax.axis_index(axes)


# ---------------------------------------------------------------------------
# distributed attack injection (training-time fault simulation)
# ---------------------------------------------------------------------------

def inject_attack(grads, key, cfg: ByzantineConfig, axes):
    """Corrupt this worker's gradient if its (flattened) index < ⌊αm⌋.

    Mirrors core.attacks.* but runs per-worker inside shard_map."""
    if cfg.attack in ("none", "label_flip") or cfg.alpha <= 0:
        return grads
    m = axis_size(axes)
    idx = worker_index(axes)
    is_byz = idx < int(cfg.alpha * m)

    if cfg.attack == "gaussian":
        key = jax.random.fold_in(key, idx)
        def leaf(g, k):
            noise = jax.random.normal(k, g.shape, jnp.float32) * cfg.gaussian_std
            return jnp.where(is_byz, noise.astype(g.dtype), g)
        leaves, td = jax.tree.flatten(grads)
        keys = jax.random.split(key, len(leaves))
        return jax.tree.unflatten(td, [leaf(g, k) for g, k in zip(leaves, keys)])

    if cfg.attack == "scale":
        return jax.tree.map(
            lambda g: jnp.where(is_byz, g * cfg.attack_scale, g), grads)

    if cfg.attack == "sign_flip":
        return jax.tree.map(lambda g: jnp.where(is_byz, -g, g), grads)

    if cfg.attack == "negation":
        def leaf(g):
            honest = jax.lax.psum(jnp.where(is_byz, 0.0, g.astype(jnp.float32)), axes)
            evil = (-cfg.attack_scale * honest).astype(g.dtype)
            return jnp.where(is_byz, evil, g)
        return jax.tree.map(leaf, grads)

    raise ValueError(f"unknown attack {cfg.attack!r}")


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def robust_aggregate(grads, cfg: ByzantineConfig, axes=("data",),
                     layout: str = "gather"):
    """Aggregate a gradient pytree across the worker axes.

    Returns the aggregated pytree (identical on every worker) plus the
    selection diagnostics (BrSGDState for ``brsgd``, SelectionState for
    the other row-selection rules, None for per-dimension rules and the
    mean fast path).
    Dispatches any aggregator registered in :mod:`.engine`;
    ``cfg.aggregator == "mean"`` reduces to a plain pmean (the
    non-robust baseline fast path).
    """
    return engine.aggregate_sharded(grads, cfg, axes=axes, layout=layout)
