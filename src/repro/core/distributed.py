"""Distributed robust aggregation inside ``shard_map``: the thin
collective-facing wrapper over the layout-aware engine.

The paper's master/worker exchange maps onto TPU collectives through
:mod:`.engine`, which executes ANY registered aggregator (all seven:
mean, median, trimmed_mean, krum, multi_krum, geomedian, brsgd) in one
of two collective layouts:

  gather (paper-faithful "master collects G"):
      per leaf:  all_gather over the worker axes -> G_leaf [m, cols].
      Statistics, selection and combine run redundantly on every
      device -> m× transient memory, all_gather wire volume.

  a2a layout (beyond-paper, §Perf):
      per leaf:  flatten, zero-pad to m·⌈D/m⌉, all_to_all over the
      worker axes -> each device owns ALL workers for 1/m of the dims.
      Per-worker statistic partials finish with one psum of
      [m]-vectors ([m,m] for the Gram matrix), selection is replicated,
      and the aggregated chunk is re-assembled with a tiled all_gather.
      Transient memory 1× instead of m×; compute per device /m.

Both layouts produce the same aggregate up to f32 summation order
(identical per-dimension math; see tests/test_engine.py for the
layout-parity matrix).  What runs where is decided by the aggregator's
registry entry — per-dimension ``column`` rules (median, trimmed mean)
never need a replicated phase, ``select`` rules ship only [m]-sized
state across workers.  To add an aggregator distributed, register it
once in ``engine.py``; nothing here changes.

This module keeps the shard_map-facing aggregation API
(``robust_aggregate``); training-time fault injection lives in
:mod:`.threat` (``threat.inject`` — the same AttackSpec registry the
dense and blocked scopes execute).  Must be called inside a FULL-manual
shard_map (every mesh axis manual — DESIGN.md §Mesh); tensor-sharded
leaves arrive as this device's 'model' shard and are declared via
``model_axes``/``leaf_specs``.
"""
from __future__ import annotations

import jax

from ..configs.base import ByzantineConfig
from . import engine


def worker_index(axes):
    return jax.lax.axis_index(axes)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def robust_aggregate(grads, cfg: ByzantineConfig, axes=("data",),
                     layout: str = "gather", flatten_columns: bool = False,
                     model_axes=(), leaf_specs=None, valid=None):
    """Aggregate a gradient pytree across the worker axes.

    Returns the aggregated pytree (identical on every worker, model
    shards intact) plus the selection diagnostics (BrSGDState for
    ``brsgd``, SelectionState for the other row-selection rules, None
    for per-dimension rules and the mean fast path).
    Dispatches any aggregator registered in :mod:`.engine`;
    ``cfg.aggregator == "mean"`` reduces to a plain pmean (the
    non-robust baseline fast path).  Must run inside a FULL-manual
    shard_map; on meshes with tensor-parallel axes pass them as
    ``model_axes`` plus each leaf's PartitionSpec as ``leaf_specs`` (see
    ``engine.aggregate_sharded``).  ``valid`` ([m] 0/1, replicated)
    opts into the elastic quorum path (DESIGN.md §Elastic): inactive
    workers contribute exact zeros and never enter selection.
    """
    return engine.aggregate_sharded(grads, cfg, axes=axes, layout=layout,
                                    flatten_columns=flatten_columns,
                                    model_axes=model_axes,
                                    leaf_specs=leaf_specs, valid=valid)
