"""Distributed BrSGD: cross-worker robust aggregation inside
``jax.shard_map`` (partial-manual over the worker mesh axes).

The paper's master/worker exchange maps onto TPU collectives:

  baseline  (paper-faithful "master collects G"):
      per leaf:  all_gather over worker axes -> G_leaf [m, ...]
      stats locally per dimension, masked mean locally.
      Every device redundantly holds all m workers' values for the
      dims it owns -> m× transient memory, all_gather volume.

  a2a layout (beyond-paper, §Perf):
      per leaf:  flatten, pad to m·⌈D/m⌉, reshape [m, D/m],
      all_to_all over worker axes  -> each device owns ALL workers for
      1/m of the dims.  Stats are local, per-worker reductions finish
      with one psum of an [m]-vector, masked mean is local, and the
      aggregated chunk is re-assembled with a tiled all_gather.
      Transient memory 1× instead of m×; compute per device /m.

Both produce bit-identical aggregates (same per-dimension math).

Must be called inside a shard_map whose manual axes == ``axes`` (the
worker axes); the 'model' mesh axis stays auto, so leaves may be
arbitrarily tensor-sharded — the math here never notices.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from ..configs.base import ByzantineConfig
from ..kernels import ref
from .aggregators import brsgd_select


def axis_size(axes) -> int:
    return int(jax.lax.axis_size(axes))


def worker_index(axes):
    return jax.lax.axis_index(axes)


# ---------------------------------------------------------------------------
# distributed attack injection (training-time fault simulation)
# ---------------------------------------------------------------------------

def inject_attack(grads, key, cfg: ByzantineConfig, axes):
    """Corrupt this worker's gradient if its (flattened) index < ⌊αm⌋.

    Mirrors core.attacks.* but runs per-worker inside shard_map."""
    if cfg.attack in ("none", "label_flip") or cfg.alpha <= 0:
        return grads
    m = axis_size(axes)
    idx = worker_index(axes)
    is_byz = idx < int(cfg.alpha * m)

    if cfg.attack == "gaussian":
        key = jax.random.fold_in(key, idx)
        def leaf(g, k):
            noise = jax.random.normal(k, g.shape, jnp.float32) * cfg.gaussian_std
            return jnp.where(is_byz, noise.astype(g.dtype), g)
        leaves, td = jax.tree.flatten(grads)
        keys = jax.random.split(key, len(leaves))
        return jax.tree.unflatten(td, [leaf(g, k) for g, k in zip(leaves, keys)])

    if cfg.attack == "scale":
        return jax.tree.map(
            lambda g: jnp.where(is_byz, g * cfg.attack_scale, g), grads)

    if cfg.attack == "sign_flip":
        return jax.tree.map(lambda g: jnp.where(is_byz, -g, g), grads)

    if cfg.attack == "negation":
        def leaf(g):
            honest = jax.lax.psum(jnp.where(is_byz, 0.0, g.astype(jnp.float32)), axes)
            evil = (-cfg.attack_scale * honest).astype(g.dtype)
            return jnp.where(is_byz, evil, g)
        return jax.tree.map(leaf, grads)

    raise ValueError(f"unknown attack {cfg.attack!r}")


# ---------------------------------------------------------------------------
# leaf-wise statistics
# ---------------------------------------------------------------------------

def _leaf_stats_gather(g, axes):
    """g: this worker's gradient leaf.  Returns (G_m [m,...], partial
    scores [m], partial l1 [m], median stack) computed from an
    all_gather along the worker axes.  The collective moves the leaf in
    its own dtype (§Perf); statistics upcast locally."""
    G = jax.lax.optimization_barrier(jax.lax.all_gather(g, axes)) \
        .astype(jnp.float32)                                 # [m, ...]
    m = G.shape[0]
    mean_c = jnp.mean(G, axis=0, keepdims=True)
    above = G >= mean_c
    n_above = jnp.sum(above.astype(jnp.int32), axis=0, keepdims=True)
    M = jnp.where(n_above * 2 >= m, above, ~above)
    red = tuple(range(1, G.ndim))
    scores = jnp.sum(M.astype(jnp.float32), axis=red)
    med = jnp.median(G, axis=0)
    l1 = jnp.sum(jnp.abs(G - med[None]), axis=red)
    return G, scores, l1


def _flatten_chunk(g, m):
    """Flatten leaf and reshape to [m, ceil(D/m)] (zero-padded)."""
    flat = g.reshape(-1)
    D = flat.shape[0]
    c = math.ceil(D / m)
    flat = jnp.pad(flat, (0, m * c - D))
    return flat.reshape(m, c), D


def _leaf_stats_a2a(g, axes, m):
    """all_to_all layout: returns (G_chunk [m, D/m], partial scores,
    partial l1) where partials must be psum'd over ``axes``.  The wire
    moves the leaf's own dtype; stats upcast locally (§Perf)."""
    x, D = _flatten_chunk(g, m)
    Gc = jax.lax.optimization_barrier(
        jax.lax.all_to_all(x, axes, split_axis=0, concat_axis=0,
                           tiled=False)).astype(jnp.float32)
    # Gc[r] = worker r's chunk for this device's dim range.
    # zero-pad columns exist only on the last chunk owner; they
    # contribute +1 per worker to scores (subtracted globally) and 0 l1.
    mean_c = jnp.mean(Gc, axis=0, keepdims=True)
    above = Gc >= mean_c
    n_above = jnp.sum(above.astype(jnp.int32), axis=0, keepdims=True)
    M = jnp.where(n_above * 2 >= m, above, ~above)
    scores = jnp.sum(M.astype(jnp.float32), axis=1)
    med = jnp.median(Gc, axis=0)
    l1 = jnp.sum(jnp.abs(Gc - med[None]), axis=1)
    pad = Gc.shape[1] * m - D
    return Gc, scores, l1, pad


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def robust_aggregate(grads, cfg: ByzantineConfig, axes=("data",),
                     layout: str = "gather"):
    """BrSGD aggregation of a gradient pytree across worker axes.

    Returns the aggregated pytree (identical on every worker) plus the
    selection diagnostics.  For cfg.aggregator == "mean" this reduces
    to a plain pmean (the non-robust baseline).  "median" aggregates
    with the coordinate-wise median (Yin et al.).
    """
    if cfg.aggregator == "mean":
        return jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads), None

    m = axis_size(axes)
    leaves, tdef = jax.tree.flatten(grads)

    if cfg.aggregator == "median":
        if layout == "a2a":
            out = []
            for g in leaves:
                Gc, _, _, _ = _leaf_stats_a2a(g, axes, m)
                med = jnp.median(Gc, axis=0)
                full = jax.lax.all_gather(med.astype(g.dtype), axes, tiled=True)
                out.append(full[:g.size].reshape(g.shape))
            return jax.tree.unflatten(tdef, out), None
        out = [jnp.median(jax.lax.all_gather(g.astype(jnp.float32), axes), axis=0)
               .astype(g.dtype) for g in leaves]
        return jax.tree.unflatten(tdef, out), None

    assert cfg.aggregator == "brsgd", cfg.aggregator

    # ---- phase 1: per-leaf stats ----
    scores = jnp.zeros((m,), jnp.float32)
    l1 = jnp.zeros((m,), jnp.float32)
    cached = []
    if layout == "a2a":
        total_pad = 0
        for g in leaves:
            Gc, s, l, pad = _leaf_stats_a2a(g, axes, m)
            cached.append(Gc)
            scores, l1 = scores + s, l1 + l
            total_pad += pad
        scores, l1 = jax.lax.psum((scores, l1), axes)
        # remove the pad columns' uniform score contribution
        scores = scores - total_pad
    else:
        for g in leaves:
            G, s, l = _leaf_stats_gather(g, axes)
            cached.append(G)
            scores, l1 = scores + s, l1 + l

    # ---- phase 2: selection (replicated) + masked mean ----
    st = brsgd_select(scores, l1, cfg.beta, cfg.threshold)
    w = st.selected.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    out = []
    if layout == "a2a":
        for g, Gc in zip(leaves, cached):
            agg_c = jnp.tensordot(w, Gc, axes=1) / denom     # [D/m]
            # re-replicate in the gradient's own dtype (§Perf)
            full = jax.lax.all_gather(agg_c.astype(g.dtype), axes, tiled=True)
            out.append(full[:g.size].reshape(g.shape))
        # stop XLA hoisting the optimizer's f32 upcast back across the
        # all_gather (it would re-widen the wire to f32)
        out = list(jax.lax.optimization_barrier(tuple(out)))
    else:
        for g, G in zip(leaves, cached):
            agg = jnp.tensordot(w, G, axes=([0], [0])) / denom
            out.append(agg.astype(g.dtype))
    return jax.tree.unflatten(tdef, out), st
