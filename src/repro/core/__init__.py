"""The paper's contribution: BrSGD robust aggregation (Algorithm 2),
baseline aggregators, Byzantine attack models, and the layout-aware
aggregation engine driving the distributed (shard_map) and
single-process (vmap) execution paths."""
from .aggregators import AGGREGATORS, aggregate, brsgd, brsgd_select, krum
from .attacks import GRADIENT_ATTACKS, apply_attack, byzantine_mask
from .distributed import inject_attack, robust_aggregate
from .engine import AggregatorSpec, aggregate_local, aggregate_sharded, register
from .simulate import make_sim_step, tree_to_vec, vec_to_tree, worker_grad_matrix
