"""The paper's contribution: BrSGD robust aggregation (Algorithm 2),
baseline aggregators, Byzantine attack models, and the distributed
(shard_map) and single-process (vmap) execution paths."""
from .aggregators import AGGREGATORS, aggregate, brsgd, brsgd_select, krum
from .attacks import GRADIENT_ATTACKS, apply_attack, byzantine_mask
from .distributed import inject_attack, robust_aggregate
from .simulate import make_sim_step, tree_to_vec, vec_to_tree, worker_grad_matrix
