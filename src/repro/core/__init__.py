"""The paper's contribution: BrSGD robust aggregation (Algorithm 2),
baseline aggregators, the layout-aware aggregation engine driving the
distributed (shard_map) and single-process (vmap) execution paths, and
the AttackSpec threat-model engine (Byzantine fault injection in every
scope)."""
from .aggregators import AGGREGATORS, aggregate, brsgd, brsgd_select, krum
from .distributed import robust_aggregate
from .engine import AggregatorSpec, aggregate_local, aggregate_sharded, register
from .simulate import make_sim_step, tree_to_vec, vec_to_tree, worker_grad_matrix
from .threat import AttackSpec, apply_dense, inject, membership_mask
