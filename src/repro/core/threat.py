"""Layout-aware threat-model engine: one AttackSpec registry drives
Byzantine fault injection in every execution scope.

Before this module existed the attack layer was written three times
with divergent coverage: ``core/attacks.py`` implemented 6 gradient
attacks on the dense [m, d] matrix, ``core/distributed.py`` re-derived
4 of them per-worker inside shard_map, and raised ``ValueError`` for
``alie``/``ipm`` in every distributed and blocked run.  This registry
mirrors ``engine.AggregatorSpec``: each attack declares WHAT it knows
about the honest workers, never HOW a scope obtains that knowledge.

Registry contract
-----------------
An :class:`AttackSpec` declares:

* ``scope`` — ``"gradient"`` (corrupts the worker-gradient values) or
  ``"data"`` (corrupts the byzantine workers' training data in the
  pipeline; gradients then look legitimate, e.g. label_flip).

* ``knows`` — the omniscient-adversary statistics the corruption rule
  reads (Blanchard et al. 2017: the adversary sees all honest
  gradients), a subset of :data:`KNOWLEDGE`:

    ``hsum``    Σ_{honest i} g_i     (per coordinate, same shape as g)
    ``hsqsum``  Σ_{honest i} g_i²    (per coordinate)

  Every knowledge statistic is element-wise per coordinate and additive
  over the honest workers, so any scope can compute it: the dense
  executor masks and sums over the worker axis of G, the shard_map and
  blocked executors zero the byzantine contribution and ``psum`` over
  the worker mesh axes — the exact contract ``engine.leaf_stats`` uses
  for aggregation statistics.  The honest count ``n_honest = m - ⌊αm⌋``
  rides along as a scalar whenever ``knows`` is non-empty.

* ``corrupt`` — a pure rule ``(g, know, key, cfg) -> evil`` mapping ONE
  worker's gradient leaf (any shape) plus the matching knowledge
  entries to that worker's byzantine replacement.  The executor applies
  ``where(is_byz, evil, g)``; the rule never sees the layout.

* ``corrupt_labels`` — for data-scope specs, the pure label/token map
  ``(values, n_classes) -> values'`` the pipelines apply to byzantine
  workers' shards.

* ``shared_row`` — declares the corrupt rule worker-independent (it
  reads only the knowledge and the config, never g/key), so the dense
  executor computes ONE evil row and broadcasts it over the byzantine
  set instead of vmapping the rule over m identical rows.

Membership
----------
Adversary identity is a declared scenario knob (``cfg.membership``),
not an implicit ``arange < ⌊αm⌋``:

  ``prefix``    workers 0..⌊αm⌋-1 (paper setting — identity arbitrary)
  ``random``    a fixed random subset drawn from ``cfg.byz_seed``
  ``resample``  a fresh subset per call, drawn from the step key

All policies corrupt exactly ``⌊αm⌋`` workers; only identity varies.
In blocked scope every bucket derives membership from the SAME step key
(the bucket/layer folds only perturb the noise key), so one consistent
byzantine set attacks the whole model.

Executors
---------
``apply_dense``  G [m, d] single-host (simulate.py, benchmarks).
``inject``       per-worker pytree inside shard_map — serves BOTH the
                 global scope (training/step.py, either collective
                 layout) and the blocked scope (core/blocked.py calls
                 it per bucket inside the backward scan).

Both derive identical per-(worker, leaf) noise keys, so dense and
sharded corruption agree to numerical tolerance (tests/test_threat.py
pins the dense↔gather↔a2a↔blocked parity matrix).

Adding an attack is one :func:`register` call — it is then available in
the dense simulation, under shard_map in both layouts, per-bucket at
blocked scale, to ``benchmarks/robustness.py`` and to the
``launch/train.py`` CLI.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import axis_size
from ..configs.base import ByzantineConfig

KNOWLEDGE = ("hsum", "hsqsum")
MEMBERSHIP_POLICIES = ("prefix", "random", "resample")

# domain-separates the membership draw from every noise key (noise keys
# fold in worker/bucket/layer indices, which are small non-negative ints)
_MEMBERSHIP_TAG = 0x6279_7A6D  # "byzm"


# ---------------------------------------------------------------------------
# byzantine membership — a declared scenario knob
# ---------------------------------------------------------------------------

def n_byzantine(cfg: ByzantineConfig, m: int, n_active=None):
    """⌊αm⌋ — every policy corrupts exactly this many workers.

    ``n_active`` (traced, elastic rounds) draws over the ACTIVE set
    instead: the adversary controls a FRACTION of whichever workers
    actually make the round, ⌊α·n_active⌋ — the bound
    ``ByzantineConfig.__post_init__`` validates the quorum against."""
    if n_active is None:
        return int(cfg.alpha * m)
    return (cfg.alpha * n_active.astype(jnp.float32)).astype(jnp.int32)


def membership_mask(cfg: ByzantineConfig, m: int, key=None, active=None):
    """[m] bool — which workers are byzantine under ``cfg.membership``.

    ``key`` (the step key) is read only by the ``resample`` policy;
    ``random`` draws from ``cfg.byz_seed`` so the subset is fixed for a
    run, and ``prefix`` is key-free.  Identical on every worker for a
    given key, so all buckets/leaves of one step see ONE byzantine set.

    ``active`` ([m] 0/1, elastic rounds) restricts the draw to the
    active workers: ⌊α·n_active⌋ byzantines, all of them active —
    "prefix" takes the first that many active slots, the keyed policies
    rank active workers by random priority (dropped slots get +inf
    priority, so they are never drawn).  All counts stay traced: one
    compiled graph serves every active set.
    """
    if active is not None:
        v = active > 0
        nb = n_byzantine(cfg, m, jnp.sum(v.astype(jnp.int32)))
        if cfg.membership == "prefix":
            return v & (jnp.cumsum(v.astype(jnp.int32)) <= nb)
        if cfg.membership == "random":
            mkey = jax.random.PRNGKey(cfg.byz_seed)
        elif cfg.membership == "resample":
            if key is None:
                raise ValueError("membership='resample' needs the step key")
            mkey = jax.random.fold_in(key, _MEMBERSHIP_TAG)
        else:
            raise ValueError(f"unknown membership policy {cfg.membership!r}; "
                             f"choose from {MEMBERSHIP_POLICIES}")
        prio = jnp.where(v, jax.random.uniform(mkey, (m,)), jnp.inf)
        rank = jnp.sum((prio[None, :] < prio[:, None]).astype(jnp.int32),
                       axis=1)
        return v & (rank < nb)
    n_byz = n_byzantine(cfg, m)
    if cfg.membership == "prefix" or n_byz == 0:
        return jnp.arange(m) < n_byz
    if cfg.membership == "random":
        mkey = jax.random.PRNGKey(cfg.byz_seed)
    elif cfg.membership == "resample":
        if key is None:
            raise ValueError("membership='resample' needs the step key")
        mkey = jax.random.fold_in(key, _MEMBERSHIP_TAG)
    else:
        raise ValueError(f"unknown membership policy {cfg.membership!r}; "
                         f"choose from {MEMBERSHIP_POLICIES}")
    perm = jax.random.permutation(mkey, m)
    return jnp.zeros((m,), bool).at[perm[:n_byz]].set(True)


def data_membership(cfg: ByzantineConfig, m: int, step: int = 0) -> np.ndarray:
    """NumPy-side membership mask for data-scope corruption (the
    pipelines run outside jit and have no step key; ``resample`` draws
    from ``byz_seed`` folded with the step index instead)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.byz_seed), step)
    return np.asarray(membership_mask(cfg, m, key))


# ---------------------------------------------------------------------------
# attack registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttackSpec:
    """Scope-independent description of one Byzantine attack."""
    name: str
    scope: str = "gradient"             # "gradient" | "data" | "timing"
    knows: frozenset = frozenset()      # honest stats the rule reads
    corrupt: Optional[Callable] = None  # (g, know, key, cfg) -> evil
    corrupt_labels: Optional[Callable] = None  # (y, n_classes) -> y'
    # timing-scope rule: maps the per-worker arrival delays of one
    # elastic round (numpy [m] float, +inf = never arrives) to the
    # adversarially delayed ones — (delays, is_byz, cfg) -> delays'.
    # Executed numpy-side by data.pipeline.ArrivalSchedule (arrival
    # timing lives outside jit, like data-scope corruption); gradients
    # stay untouched, the damage is WHO makes the quorum.
    delay: Optional[Callable] = None
    # worker-independent rule: corrupt ignores (g, key), so every
    # byzantine worker emits the SAME evil values (negation/alie/ipm —
    # pure functions of the honest statistics).  The dense executor then
    # computes ONE evil row and broadcasts it instead of vmapping the
    # rule over m identical rows.
    shared_row: bool = False

    def __post_init__(self):
        if self.scope not in ("gradient", "data", "timing"):
            raise ValueError(f"{self.name}: unknown scope {self.scope!r}")
        if self.shared_row and self.scope != "gradient":
            raise ValueError(f"{self.name}: shared_row is a gradient-scope "
                             f"property")
        if (self.scope == "gradient") != (self.corrupt is not None):
            raise ValueError(
                f"{self.name}: gradient specs set corrupt, other scopes "
                f"don't")
        if (self.scope == "data") != (self.corrupt_labels is not None):
            raise ValueError(
                f"{self.name}: data specs set corrupt_labels, other scopes "
                f"don't")
        if (self.scope == "timing") != (self.delay is not None):
            raise ValueError(
                f"{self.name}: timing specs set delay, other scopes don't")
        unknown = set(self.knows) - set(KNOWLEDGE)
        if unknown:
            raise ValueError(f"{self.name}: unknown knowledge "
                             f"{sorted(unknown)}")


_REGISTRY: dict[str, AttackSpec] = {}


def register(spec: AttackSpec) -> AttackSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> AttackSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown attack {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---- corruption rules (paper §5.1 + literature) ----------------------------

def _gaussian(g, know, key, cfg):
    """Replace byzantine values with N(0, std²) noise (paper: std=200).

    When the executor runs on a model-sharded view of the leaf it passes
    the GLOBAL leaf shape and this shard's offsets via the knowledge dict
    (``noise_shape``/``noise_start`` — see :func:`inject`): the noise is
    drawn for the full leaf and sliced, so every layout produces
    bit-identical noise regardless of how the leaf is sharded."""
    shape = know.get("noise_shape", g.shape)
    noise = jax.random.normal(key, shape, jnp.float32) * cfg.gaussian_std
    if shape != g.shape:
        noise = jax.lax.dynamic_slice(noise, know["noise_start"], g.shape)
    return noise


def _negation(g, know, key, cfg):
    """Model Negation: -(sum of honest gradients) * c."""
    return -cfg.negation_factor * know["hsum"]


def _scale(g, know, key, cfg):
    """Gradient Scale: own gradient scaled by a large constant."""
    return g.astype(jnp.float32) * cfg.scale_factor


def _sign_flip(g, know, key, cfg):
    """Extra (not in paper): byzantine workers negate their gradient."""
    return -g.astype(jnp.float32)


def _alie(g, know, key, cfg):
    """ALIE — "A Little Is Enough" (Baruch et al., 2019): move z
    standard deviations from the honest mean, per coordinate — small
    enough to pass distance filters, coordinated enough to bias the
    aggregate.  z = cfg.alie_z (classic z_max heuristic ~1.5)."""
    n = know["n_honest"]
    mu = know["hsum"] / n
    var = jnp.maximum(know["hsqsum"] / n - mu * mu, 0.0)
    return mu - cfg.alie_z * jnp.sqrt(var)


def _ipm(g, know, key, cfg):
    """IPM — Inner-Product Manipulation (Xie et al., 2020):
    -ε·mean(honest): for small ε the corrupted mean keeps a POSITIVE
    inner product with the honest direction but is shrunk/reversed
    enough to stall convergence."""
    return -cfg.ipm_eps * (know["hsum"] / know["n_honest"])


register(AttackSpec("gaussian", corrupt=_gaussian))
register(AttackSpec("negation", knows=frozenset({"hsum"}),
                    corrupt=_negation, shared_row=True))
register(AttackSpec("scale", corrupt=_scale))
register(AttackSpec("sign_flip", corrupt=_sign_flip))
register(AttackSpec("alie", knows=frozenset({"hsum", "hsqsum"}),
                    corrupt=_alie, shared_row=True))
register(AttackSpec("ipm", knows=frozenset({"hsum"}), corrupt=_ipm,
                    shared_row=True))
# the paper's Label Shift: y -> (n_classes - 1) - y on byzantine shards.
# Data corruption happens in data/pipeline.py; gradients stay untouched.
register(AttackSpec("label_flip", scope="data",
                    corrupt_labels=lambda y, n_classes: n_classes - 1 - y))
# byzantine workers stall the round (never arrive): in an elastic round
# the quorum must fill from honest stragglers — or run short-handed when
# it can't.  Measures the availability cost of quorum selection under a
# denial-of-contribution adversary (no gradient is ever corrupted).
register(AttackSpec("stall", scope="timing",
                    delay=lambda d, is_byz, cfg: np.where(is_byz, np.inf, d)))


def is_gradient_attack(cfg: ByzantineConfig) -> bool:
    """True when cfg names a registered gradient-scope attack that will
    actually fire (alpha > 0)."""
    if cfg.attack == "none" or cfg.alpha <= 0:
        return False
    return get_spec(cfg.attack).scope == "gradient"


def inject_collectives(cfg: ByzantineConfig, n_leaves: int,
                       m: Optional[int] = None) -> dict:
    """Expected per-call collective counts of :func:`inject` — the
    threat layer's half of the lint contract (``analysis/rules.py``
    adds these to the engine's own when a traced step injects an
    attack).  Knowledge-free attacks are collective-free; omniscient
    attacks psum one honest moment per declared knowledge entry PER
    LEAF (``_sharded_knowledge``)."""
    if not is_gradient_attack(cfg) or (m is not None
                                       and n_byzantine(cfg, m) == 0):
        return {"all_reduce": 0, "axis_index": 0}
    knows = len(get_spec(cfg.attack).knows)
    return {"all_reduce": knows * n_leaves, "axis_index": 1}


# ---------------------------------------------------------------------------
# knowledge — the omniscient-adversary statistics, computed per scope
# ---------------------------------------------------------------------------

def _finish_knowledge(know: dict, knows, n_honest) -> dict:
    if knows:
        # n_honest is a Python int in a fixed-m round and a traced count
        # in an elastic one (honest = active minus byzantine)
        know["n_honest"] = jnp.asarray(n_honest, jnp.float32)
    return know


def _dense_knowledge(G, mask, knows, n_honest, active=None) -> dict:
    """Honest per-coordinate moments from the full [m, d] matrix.  In an
    elastic round ``active`` additionally excludes dropped workers: the
    adversary can only read gradients that were actually produced."""
    know = {}
    if knows:
        drop = mask if active is None else (mask | ~(active > 0))
        keep = jnp.where(drop[:, None], 0.0, G.astype(jnp.float32))
        if "hsum" in knows:
            know["hsum"] = jnp.sum(keep, axis=0)
        if "hsqsum" in knows:
            know["hsqsum"] = jnp.sum(keep * keep, axis=0)
    return _finish_knowledge(know, knows, n_honest)


def _sharded_knowledge(g, is_byz, knows, axes, n_honest,
                       is_active=None) -> dict:
    """Same moments inside shard_map: zero this worker's contribution if
    byzantine (or dropped, in an elastic round), psum over the worker
    axes — additive exactly like ``engine.leaf_stats`` partials."""
    know = {}
    if knows:
        drop = is_byz if is_active is None else (is_byz | ~is_active)
        keep = jnp.where(drop, 0.0, g.astype(jnp.float32))
        if "hsum" in knows:
            know["hsum"] = jax.lax.psum(keep, axes)
        if "hsqsum" in knows:
            know["hsqsum"] = jax.lax.psum(keep * keep, axes)
    return _finish_knowledge(know, knows, n_honest)


def _leaf_key(key, worker, leaf: int):
    """Per-(worker, leaf) noise key — the SAME derivation in every
    scope, so dense and sharded gaussian noise are bit-identical."""
    return jax.random.fold_in(jax.random.fold_in(key, worker), leaf)


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

def apply_dense(G, key, cfg: ByzantineConfig, active=None):
    """Corrupt the byzantine rows of the dense worker-gradient matrix
    G [m, d].  Data-scope and timing-scope attacks and alpha=0 are
    no-ops here (data corruption happens in the pipeline; arrival timing
    in the ArrivalSchedule).  ``active`` ([m] 0/1) scopes an elastic
    round: membership and knowledge draw over the active set only."""
    if not is_gradient_attack(cfg):
        return G
    spec = get_spec(cfg.attack)
    m = G.shape[0]
    if active is None:
        n_byz = n_byzantine(cfg, m)
        if n_byz == 0:
            return G
        mask = membership_mask(cfg, m, key)
        n_honest = m - n_byz
    else:
        na = jnp.sum((active > 0).astype(jnp.int32))
        mask = membership_mask(cfg, m, key, active)
        n_honest = na - n_byzantine(cfg, m, na)
    know = _dense_knowledge(G, mask, spec.knows, n_honest, active)
    if spec.shared_row:
        # worker-independent rule: ONE evil row, broadcast over the
        # byzantine set (g and key are ignored by the rule)
        evil = spec.corrupt(G[0], know, key, cfg)
        return jnp.where(mask[:, None], evil[None].astype(G.dtype), G)
    keys = jax.vmap(lambda i: _leaf_key(key, i, 0))(jnp.arange(m))
    evil = jax.vmap(lambda g, k: spec.corrupt(g, know, k, cfg))(G, keys)
    return jnp.where(mask[:, None], evil.astype(G.dtype), G)


def _noise_view(g, pspec, model_axes):
    """(global shape, per-dim start indices) of this device's view of a
    leaf sharded over ``model_axes`` — identity when the leaf is
    replicated over them.  Lets key-driven corruption rules (gaussian)
    draw noise for the FULL leaf and slice their shard, so the injected
    values are invariant to the mesh's model sharding."""
    if pspec is None or not model_axes:
        return g.shape, None
    shape, start = list(g.shape), [0] * g.ndim
    sharded = False
    for dim, entry in enumerate(pspec):
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(a for a in names if a in model_axes)
        if not names:
            continue
        n = axis_size(names)
        shape[dim] = g.shape[dim] * n
        start[dim] = jax.lax.axis_index(names) * g.shape[dim]
        sharded = True
    if not sharded:
        return g.shape, None
    return tuple(shape), tuple(jnp.int32(s) for s in start)


def inject(grads, key, cfg: ByzantineConfig, axes, membership_key=None,
           leaf_specs=None, model_axes=(), active=None):
    """Corrupt this worker's gradient pytree inside shard_map (global
    scope before aggregation, or one bucket inside the blocked backward
    scan).

    ``key`` drives the noise (the blocked scope folds bucket/layer ids
    into it so noise decorrelates across buckets and layers);
    ``membership_key`` — when given — drives WHO is byzantine instead,
    so every bucket of a step shares one membership draw (defaults to
    ``key``).

    ``leaf_specs``/``model_axes``: when the caller runs full-manual on a
    mesh with tensor-parallel axes, each leaf is this device's model
    shard.  Per-coordinate knowledge still psums over the worker axes
    only (the coordinates ARE the shard), but key-driven rules receive
    the global leaf shape + shard offsets through the knowledge dict so
    their noise is sharding-invariant (see :func:`_gaussian`).

    ``active`` ([m] 0/1, replicated — elastic rounds): membership and
    knowledge draw over the active workers only; dropped workers are
    never corrupted (the engine zeroes them out anyway)."""
    if not is_gradient_attack(cfg):
        return grads
    spec = get_spec(cfg.attack)
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    m = axis_size(axes)
    idx = jax.lax.axis_index(axes)
    mkey = key if membership_key is None else membership_key
    if active is None:
        n_byz = n_byzantine(cfg, m)
        if n_byz == 0:
            return grads
        is_byz = membership_mask(cfg, m, mkey)[idx]
        n_honest = m - n_byz
        is_active = None
    else:
        na = jnp.sum((active > 0).astype(jnp.int32))
        is_byz = membership_mask(cfg, m, mkey, active)[idx]
        n_honest = na - n_byzantine(cfg, m, na)
        is_active = (active > 0)[idx]
    leaves, tdef = jax.tree.flatten(grads)
    if leaf_specs is None:
        spec_leaves = [None] * len(leaves)
    else:
        from jax.sharding import PartitionSpec as P
        # keep None ("replicated") entries as leaves — dropping them
        # would misalign every following spec with its gradient leaf
        spec_leaves = jax.tree.leaves(
            leaf_specs, is_leaf=lambda x: x is None or isinstance(x, P))
        assert len(spec_leaves) == len(leaves), \
            (len(spec_leaves), len(leaves))
    out = []
    for li, (g, ps) in enumerate(zip(leaves, spec_leaves)):
        know = _sharded_knowledge(g, is_byz, spec.knows, axes, n_honest,
                                  is_active)
        shape, start = _noise_view(g, ps, tuple(model_axes))
        if start is not None:
            know["noise_shape"], know["noise_start"] = shape, start
        evil = spec.corrupt(g, know, _leaf_key(key, idx, li), cfg)
        out.append(jnp.where(is_byz, evil.astype(g.dtype), g))
    return jax.tree.unflatten(tdef, out)
