"""Single-process m-worker Byzantine SGD simulation.

This is the harness for the paper's own experimental scale (m=20,
LeNet/FashionMNIST): per-worker gradients via ``vmap`` over a leading
worker axis, gradient-space attacks on the G matrix, then any of the
aggregation rules.  It runs on one CPU device — no mesh required — and
shares the aggregator/attack implementations with the distributed path.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ByzantineConfig
from . import engine, threat


def tree_to_vec(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def vec_to_tree(vec, like):
    leaves, tdef = jax.tree.flatten(like)
    out, o = [], 0
    for l in leaves:
        out.append(vec[o:o + l.size].reshape(l.shape).astype(l.dtype))
        o += l.size
    return jax.tree.unflatten(tdef, out)


def worker_grad_matrix(loss_fn: Callable, params, worker_batches):
    """G [m, d]: per-worker flattened gradients.

    worker_batches: pytree with leading worker axis m on every leaf.
    """
    grads = jax.vmap(jax.grad(loss_fn), in_axes=(None, 0))(params, worker_batches)
    return jax.vmap(tree_to_vec)(grads)


def make_sim_step(loss_fn: Callable, bcfg: ByzantineConfig, lr: float):
    """Plain-SGD simulation step (the paper trains with vanilla SGD).

    Returns ``(new_params, metrics)`` with ``metrics = {"gnorm",
    "n_selected"}`` — the selection count comes from the aggregator's
    real SelectionState, so paper-scale (m=20 LeNet) runs report the
    same truthful selection diagnostics as the distributed path
    (column rules and the mean have no selection phase: they report m).
    """

    @jax.jit
    def step(params, worker_batches, key):
        G = worker_grad_matrix(loss_fn, params, worker_batches)
        G = threat.apply_dense(G, key, bcfg)
        agg, st = engine.aggregate_local(G, bcfg, return_state=True)
        new_params = jax.tree.map(
            lambda p, g: p - lr * g.astype(p.dtype), params,
            vec_to_tree(agg, params))
        n_sel = (jnp.sum(st.selected.astype(jnp.float32)) if st is not None
                 else jnp.float32(G.shape[0]))
        return new_params, {"gnorm": jnp.linalg.norm(agg),
                            "n_selected": n_sel}

    return step
