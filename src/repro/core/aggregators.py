"""Robust gradient aggregation rules on the worker-gradient matrix
G ∈ R^{m×d}.

``brsgd`` is the paper's contribution (Algorithm 2); ``mean``,
``cwise_median`` (Yin et al., 2018), ``trimmed_mean`` (Yin et al.,
2018) and ``krum`` (Blanchard et al., 2017) are the baselines it
compares against.  All return the aggregated gradient [d].

Every rule is a thin wrapper over the layout-aware engine
(:mod:`.engine`): the registry entry there defines the rule ONCE —
its per-leaf statistics, replicated selection and combine — and these
functions execute it in the ``local`` (single-host [m, d]) layout.
The same entries power the ``gather``/``a2a`` shard_map layouts in
:mod:`.distributed`.

Complexities (paper §2): brsgd O(md); cwise median O(dm log m);
trimmed mean O(dm log m); krum O(m²(d + log m)).  Every statistic and
order statistic flows through the fused one-sort pass
(``ops.fused_stats`` / ``ref.sorted_worker_rows``; DESIGN.md §Perf),
and the replicated BrSGD selection is a sort-free counting quantile —
the measured local scaling is ~m^0.9 d^0.85 (BENCH_agg.json).
"""
from __future__ import annotations

from ..configs.base import ByzantineConfig
from . import engine
from .engine import BrSGDState, brsgd_select  # noqa: F401  (public API)

_DEFAULT = ByzantineConfig()


def brsgd(G, cfg: ByzantineConfig, use_pallas: bool | None = None,
          return_state: bool = False):
    """Paper Algorithm 2: 𝒜_{β,𝔗}({g^i})."""
    return engine.aggregate_local(G, cfg, use_pallas=use_pallas,
                                  return_state=return_state,
                                  spec=engine.get_spec("brsgd"))


def mean(G, cfg: ByzantineConfig = None, use_pallas: bool | None = None):
    """Arithmetic mean (non-robust baseline).  The jnp path accumulates
    rows sequentially (ref.masked_mean_det) so the result is
    deterministic and bit-identical to np.mean(G, axis=0)."""
    return engine.aggregate_local(G, cfg or _DEFAULT, use_pallas=use_pallas,
                                  spec=engine.get_spec("mean"))


def cwise_median(G, cfg: ByzantineConfig = None,
                 use_pallas: bool | None = None):
    return engine.aggregate_local(G, cfg or _DEFAULT, use_pallas=use_pallas,
                                  spec=engine.get_spec("median"))


def trimmed_mean(G, cfg: ByzantineConfig, use_pallas: bool | None = None):
    """Coordinate-wise trimmed mean (Yin et al. 2018), routed through
    kernels/ops.py like every other rule (Pallas on TPU)."""
    return engine.aggregate_local(G, cfg, use_pallas=use_pallas,
                                  spec=engine.get_spec("trimmed_mean"))


def krum(G, cfg: ByzantineConfig):
    """Krum (Blanchard et al. 2017): pick the gradient whose summed
    squared distance to its m - f - 2 closest neighbours is minimal."""
    return engine.aggregate_local(G, cfg, spec=engine.get_spec("krum"))


def multi_krum(G, cfg: ByzantineConfig, n_select: int = 0):
    """Multi-Krum (Blanchard et al. 2017): average the n_select rows
    with the best Krum scores (n_select defaults to m - f)."""
    spec = (engine.spec_with("multi_krum", n_select=n_select)
            if n_select else engine.get_spec("multi_krum"))
    return engine.aggregate_local(G, cfg, spec=spec)


def geometric_median(G, cfg: ByzantineConfig = None,
                     iters: int = engine.GEOMEDIAN_ITERS,
                     eps: float = engine.GEOMEDIAN_EPS):
    """Geometric median via Weiszfeld iterations (Chen et al. 2017
    baseline; the paper cites its O(dm log^3(1/eps)) cost).  See
    engine._geomedian_select for the weight-space formulation and the
    coordinate-wise-median initialization rationale."""
    spec = engine.spec_with("geomedian", iters=iters, eps=eps)
    return engine.aggregate_local(G, cfg or _DEFAULT, spec=spec)


AGGREGATORS = {
    "mean": mean,
    "median": cwise_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "multi_krum": multi_krum,
    "geomedian": geometric_median,
    "brsgd": brsgd,
}


def aggregate(G, cfg: ByzantineConfig):
    """Dispatch on cfg.aggregator.  G: [m, d] -> [d]."""
    fn = AGGREGATORS[cfg.aggregator]
    return fn(G, cfg)
