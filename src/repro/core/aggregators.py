"""Robust gradient aggregation rules on the worker-gradient matrix
G ∈ R^{m×d}.

``brsgd`` is the paper's contribution (Algorithm 2); ``mean``,
``cwise_median`` (Yin et al., 2018), ``trimmed_mean`` (Yin et al.,
2018) and ``krum`` (Blanchard et al., 2017) are the baselines it
compares against.  All return the aggregated gradient [d].

Complexities (paper §2): brsgd O(md); cwise median O(dm log m);
trimmed mean O(dm log m); krum O(m²(d + log m)).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ByzantineConfig
from ..kernels import ops, ref


class BrSGDState(NamedTuple):
    """Diagnostics of one aggregation call (useful for tests/monitoring)."""
    selected: jax.Array     # [m] bool — C1 ∩ C2 (after fallback)
    c1: jax.Array           # [m] bool — l1 filter
    c2: jax.Array           # [m] bool — top-beta score filter
    scores: jax.Array       # [m]
    l1: jax.Array           # [m]
    threshold: jax.Array    # resolved 𝔗


def brsgd_select(scores, l1, beta: float, threshold: float) -> BrSGDState:
    """Constraint 1 (ℓ1 ≤ 2𝔗) ∩ Constraint 2 (top-β by score).

    threshold <= 0 selects the auto rule 𝔗 = lower-quartile_i(l1_i):
    under honest majority (α < 1/2) the 25th percentile of the l1
    distances is attained by an honest worker, and — unlike the median —
    it stays honest at the paper's boundary setting α = 1/2, where the
    per-dimension majority tie-break alone is adversarially exploitable
    (an attacker cluster of exactly m/2 identical rows wins every tie on
    dimensions whose honest gradient sum has the right sign).  2𝔗 then
    covers the honest concentration radius (Assumption 1) while the
    Byzantine cluster's l1 — inflated by its own distance to the honest
    median — is rejected.
    """
    m = scores.shape[0]
    T = jnp.where(threshold > 0, threshold,
                  jnp.quantile(l1, 0.25, method="nearest"))
    c1 = l1 <= 2.0 * T
    k = max(1, math.ceil(beta * m))
    kth = jnp.sort(scores)[m - k]
    c2 = scores >= kth
    sel = c1 & c2
    # guard: the paper assumes C1∩C2 nonempty; if a pathological 𝔗 empties
    # it, fall back to C2 (score filter alone).
    sel = jnp.where(jnp.any(sel), sel, c2)
    return BrSGDState(sel, c1, c2, scores, l1, T)


def brsgd(G, cfg: ByzantineConfig, use_pallas: bool | None = None,
          return_state: bool = False):
    """Paper Algorithm 2: 𝒜_{β,𝔗}({g^i})."""
    kw = {} if use_pallas is None else {"use_pallas": use_pallas}
    med, _mean, scores, l1 = ops.brsgd_stats(G, **kw)
    st = brsgd_select(scores, l1, cfg.beta, cfg.threshold)
    agg = ops.masked_mean(G, st.selected, **kw)
    return (agg, st) if return_state else agg


def mean(G, cfg: ByzantineConfig = None):
    return jnp.mean(G.astype(jnp.float32), axis=0)


def cwise_median(G, cfg: ByzantineConfig = None, use_pallas: bool | None = None):
    kw = {} if use_pallas is None else {"use_pallas": use_pallas}
    return ops.cwise_median(G, **kw)


def trimmed_mean(G, cfg: ByzantineConfig):
    return ref.trimmed_mean_ref(G, cfg.trim_frac)


def krum(G, cfg: ByzantineConfig):
    """Krum (Blanchard et al. 2017): pick the gradient whose summed
    squared distance to its m - f - 2 closest neighbours is minimal."""
    m = G.shape[0]
    f = cfg.krum_f if cfg.krum_f > 0 else max(1, int(cfg.alpha * m))
    n_close = max(1, m - f - 2)
    Gf = G.astype(jnp.float32)
    sq = jnp.sum(Gf * Gf, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (Gf @ Gf.T)       # [m,m]
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf))
    nearest = jnp.sort(d2, axis=1)[:, :n_close]
    score = jnp.sum(nearest, axis=1)
    return Gf[jnp.argmin(score)]


def geometric_median(G, cfg: ByzantineConfig = None, iters: int = 16,
                     eps: float = 1e-6):
    """Geometric median via Weiszfeld iterations (Chen et al. 2017
    baseline; the paper cites its O(dm log^3(1/eps)) cost).

    Initialized at the coordinate-wise median — starting from the MEAN
    under a scale-1e10 attack leaves Weiszfeld in the flat far-field
    where all distances (hence weights) are equal."""
    Gf = G.astype(jnp.float32)

    def step(z, _):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(Gf - z[None], axis=1), eps)
        return (w @ Gf) / jnp.sum(w), None

    z0 = jnp.median(Gf, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z


def multi_krum(G, cfg: ByzantineConfig, n_select: int = 0):
    """Multi-Krum (Blanchard et al. 2017): average the n_select rows
    with the best Krum scores (n_select defaults to m - f)."""
    m = G.shape[0]
    f = cfg.krum_f if cfg.krum_f > 0 else max(1, int(cfg.alpha * m))
    n_close = max(1, m - f - 2)
    k = n_select or max(1, m - f)
    Gf = G.astype(jnp.float32)
    sq = jnp.sum(Gf * Gf, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (Gf @ Gf.T)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf))
    score = jnp.sum(jnp.sort(d2, axis=1)[:, :n_close], axis=1)
    best = jnp.argsort(score)[:k]
    return jnp.mean(Gf[best], axis=0)


AGGREGATORS = {
    "mean": mean,
    "median": cwise_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "multi_krum": multi_krum,
    "geomedian": geometric_median,
    "brsgd": brsgd,
}


def aggregate(G, cfg: ByzantineConfig):
    """Dispatch on cfg.aggregator.  G: [m, d] -> [d]."""
    fn = AGGREGATORS[cfg.aggregator]
    return fn(G, cfg)
