"""Layout-aware robust-aggregation engine.

One registry drives every aggregation rule in every execution layout.
Before this module existed the same per-leaf statistics math was written
three times (jnp reference, Pallas kernel wrapper, and inline shard_map
code) and only 3 of the 7 registered aggregators could run distributed.

Registry contract
-----------------
An :class:`AggregatorSpec` declares WHAT an aggregator needs, never HOW
a layout obtains it.  Exactly one of ``select``/``column`` is set:

* ``stats``  — the per-leaf statistics the rule consumes, a subset of
  :data:`STAT_NAMES`:

    ``scores``  [m]    majority scores (paper Alg. 2 Constraint 2)
    ``l1``      [m]    l1 distance to the coordinate-wise median
    ``d2med``   [m]    squared l2 distance to the coordinate-wise median
    ``gram``    [m,m]  pairwise Gram matrix G Gᵀ (pairwise distances
                       d²_ij = S_ii + S_jj − 2 S_ij derive from it)

  Every statistic is additive over disjoint dimension ranges, so a
  layout may compute it per leaf / per shard and sum (and, for the
  ``a2a`` layout, ``psum``) the partials.

* ``select`` — replicated rule ``(stats, cfg, m) -> (weights [m] f32,
  state | None)``.  Runs on [m]-/[m,m]-sized inputs only, identically on
  every device.  The engine then emits the weighted row combine
  ``Σ_i w_i g_i / Σ_i w_i`` in whatever layout is active.

* ``column`` — per-dimension rule ``(G [m, cols], cfg, m, **kw) ->
  [cols]`` for aggregators that are a pure map over dimensions (e.g.
  coordinate-wise median / trimmed mean).  Needs no replicated phase at
  all: each device applies it to the worker values it holds.

Adding an aggregator is one :func:`register` call — it is then
automatically available in all three layouts, to ``benchmarks/`` and to
``training/step.py``.

Layouts
-------
``local``   single-host worker-gradient matrix G [m, d] (the paper's
            experimental setting; Pallas kernels when on TPU).
``gather``  inside shard_map: all_gather per leaf over the worker axes
            — every device redundantly holds all m workers' values for
            the dims it owns (paper-faithful "master collects G").
            Select rules gather each leaf exactly ONCE, for the fused
            stats pass; the gathered view is transient (peak m× one
            leaf, not m× the model) because the weighted combine is a
            psum of each worker's own weighted gradient, never a second
            pass over gathered data.
``a2a``     inside shard_map: flatten, zero-pad to m·⌈D/m⌉, all_to_all
            — each device owns ALL workers for 1/m of the dims (1×
            transient memory); per-worker stats finish with one psum of
            [m]-vectors, the aggregated chunk is re-assembled with a
            tiled all_gather.  Zero-pad columns contribute +1 per
            worker to ``scores`` (subtracted globally) and 0 to every
            other statistic.

All layouts share :func:`leaf_stats` — the per-leaf statistics math is
written exactly once.
"""
from __future__ import annotations

import logging
import math
from dataclasses import dataclass, replace
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..compat import axis_size
from ..configs.base import ByzantineConfig
from ..kernels import ops, ref

# canonical stat names live at the kernel layer (ref.py) so the fused
# Pallas/jnp passes can share them without a circular import
STAT_NAMES = ref.STAT_NAMES

GEOMEDIAN_ITERS = 16
GEOMEDIAN_EPS = 1e-6


# ---------------------------------------------------------------------------
# BrSGD selection (paper Algorithm 2) — the replicated phase
# ---------------------------------------------------------------------------

class SelectionState(NamedTuple):
    """Generic diagnostics for select-rule aggregators that have no
    richer state of their own (krum: one row; multi_krum: m-f rows;
    geomedian: all rows, continuously weighted).  ``selected`` feeds
    the training loop's n_selected metric."""
    selected: jax.Array     # [m] bool — rows with nonzero combine weight
    weights: jax.Array      # [m] f32 — the combine weights


class BrSGDState(NamedTuple):
    """Diagnostics of one aggregation call (useful for tests/monitoring)."""
    selected: jax.Array     # [m] bool — C1 ∩ C2 (after fallback)
    c1: jax.Array           # [m] bool — l1 filter
    c2: jax.Array           # [m] bool — top-beta score filter
    scores: jax.Array       # [m]
    l1: jax.Array           # [m]
    threshold: jax.Array    # resolved 𝔗


def brsgd_select(scores, l1, beta: float, threshold: float) -> BrSGDState:
    """Constraint 1 (ℓ1 ≤ 2𝔗) ∩ Constraint 2 (top-β by score).

    threshold <= 0 selects the auto rule 𝔗 = lower-quartile_i(l1_i):
    under honest majority (α < 1/2) the 25th percentile of the l1
    distances is attained by an honest worker, and — unlike the median —
    it stays honest at the paper's boundary setting α = 1/2, where the
    per-dimension majority tie-break alone is adversarially exploitable
    (an attacker cluster of exactly m/2 identical rows wins every tie on
    dimensions whose honest gradient sum has the right sign).  2𝔗 then
    covers the honest concentration radius (Assumption 1) while the
    Byzantine cluster's l1 — inflated by its own distance to the honest
    median — is rejected.
    """
    sel, c1, c2, T = ref.brsgd_select_mask(scores, l1, beta, threshold)
    return BrSGDState(sel, c1, c2, scores, l1, T)


# ---------------------------------------------------------------------------
# per-leaf statistics — written ONCE, used by every layout
# ---------------------------------------------------------------------------

def leaf_stats(G, needs, m: int, axis: int = 0,
               use_pallas: bool | None = None, valid=None, rows=None,
               refs=None) -> dict:
    """Partial statistics of one worker view of G (f32), whose ``axis``
    indexes the m workers (worker-major [m, cols] by default).

    G may be a full local matrix, a gathered leaf, an all_to_all chunk,
    or a blocked-scope worker view with the worker axis in the middle of
    an N-D leaf — the returned partials are additive over the dimension
    ranges the views cover (psum over workers completes the a2a and
    blocked layouts).

    Delegates to ``ops.fused_stats`` — ONE pass over the view, however
    many statistics the spec declared: one HBM read on TPU, one shared
    bitonic sorted-rows pass on the reference path (the seed's version
    re-derived the coordinate-wise median per statistic through XLA's
    scalarized CPU sort).  DESIGN.md §Perf has the contract.

    ``valid`` ([m] 0/1) switches to the elastic masked pass: statistics
    of the active workers only, dropped slots as exact zeros (DESIGN.md
    §Elastic).  ``rows``/``refs`` scope the output to one arrival
    bucket against shared active-set invariants — the streaming-
    accumulator hooks (:func:`stream_leaf_stats`).
    """
    if not needs:
        return {}
    kw = {} if use_pallas is None else {"use_pallas": use_pallas}
    if valid is not None:
        kw.update(valid=valid, rows=rows, refs=refs)
    return ops.fused_stats(G, tuple(sorted(needs)), axis=axis, **kw)


def zero_stats(needs, m: int) -> dict:
    """Zero-initialized partial-stat accumulators for ``needs``."""
    return {k: jnp.zeros((m, m) if k == "gram" else (m,), jnp.float32)
            for k in needs}


def resolve_select(spec, stats: dict, cfg, m: int):
    """Run a spec's replicated select rule and resolve the combine
    denominator: ``(weights [m], state, denom)`` with the empty-selection
    guard (Σw == 0 -> divide by 1) and a synthesized SelectionState when
    the rule has no richer state.  Shared by every layout that emits the
    weighted row combine (sharded gather/a2a and the blocked scope).

    In an elastic round the validity mask rides the stats dict under the
    ``"valid"`` key: every shipped select rule masks its own quantiles
    and candidates, and this resolver re-masks the weights as defense in
    depth — no rule may keep combine weight on a dropped worker."""
    w, st = spec.select(stats, cfg, m)
    valid = stats.get("valid") if isinstance(stats, dict) else None
    if valid is not None:
        w = w * (valid > 0).astype(jnp.float32)
        if st is not None and hasattr(st, "_replace"):
            st = st._replace(selected=st.selected & (valid > 0))
    if st is None:
        st = SelectionState(w > 0, w)
    sw = jnp.sum(w)
    return w, st, jnp.where(sw > 0, sw, 1.0)


def pad_correction(stats: dict, pad, valid=None) -> dict:
    """Remove the zero-pad columns' contribution (a2a layout).

    A zero column means every worker ties at the column mean, so the
    whole column is "majority": +1 score per worker per pad column — per
    ACTIVE worker in an elastic round (dropped slots carry exact-zero
    scores, so their correction is masked too).  Median/l1/d2med/gram of
    zero columns are exactly zero.
    """
    if "scores" in stats and pad:
        stats = dict(stats)
        corr = pad if valid is None else pad * valid.astype(jnp.float32)
        stats["scores"] = stats["scores"] - corr
    return stats


# ---------------------------------------------------------------------------
# streaming (elastic) accumulator — arrival-order-invariant by construction
# ---------------------------------------------------------------------------
# Workers report in arbitrary order; their stat partials fold into a
# running state as they land.  Bit-exactness with the bulk masked
# :func:`leaf_stats` pass is by CONSTRUCTION, not by tolerance: each
# worker's output slots are non-zero in exactly one bucket's partial and
# exact zeros everywhere else (the masked zero-pad contract), the
# [d]-space invariants (column mean / majority / median) are computed
# once from the full active set and shared by every bucket, and IEEE
# ``x + 0.0 == x`` makes dict addition over disjoint slots the identity
# on each slot.  Any permutation or partition of the arrivals therefore
# folds to the same bits.  DESIGN.md §Elastic.

class StreamState(NamedTuple):
    """Running state of the streaming accumulator."""
    stats: dict             # per-worker stat partials folded so far
    valid: jax.Array        # [m] f32 — 1.0 once a worker's partial landed


def init_stream(needs, m: int) -> StreamState:
    return StreamState(zero_stats(needs, m), jnp.zeros((m,), jnp.float32))


def fold_stats(state: StreamState, partial: dict, valid) -> StreamState:
    """Fold one arrival bucket's per-worker stat partials (plus its
    [m] 0/1 arrival mask) into the running state."""
    return StreamState(
        {k: state.stats[k] + partial[k] for k in state.stats},
        state.valid + valid.astype(jnp.float32))


def fold_arrivals(buffer, valid, rows, mask):
    """G-space half of the accumulator: write one arrival bucket's
    gradient rows into the padded [max_m, ...] buffer.  Disjoint slots —
    bit-exact under any arrival order.  Returns (buffer', valid')."""
    mb = mask.astype(jnp.float32).reshape(
        (buffer.shape[0],) + (1,) * (buffer.ndim - 1))
    return jnp.where(mb > 0, rows, buffer), valid + mask.astype(jnp.float32)


def stream_leaf_stats(G, needs, m: int, arrival, axis: int = 0) -> StreamState:
    """Fold per-worker stat partials over a ``lax.scan`` of arrival
    buckets.

    ``arrival`` [n_buckets, m]: disjoint 0/1 masks — bucket b holds the
    workers whose gradients landed in arrival slot b (Σ over buckets is
    the round's validity mask).  The active-set invariants are computed
    ONCE (``ops.masked_stat_refs``); each scan step evaluates the
    bucket's per-worker partials against those fixed references and
    folds them via :func:`fold_stats`.  The returned state's stats are
    bit-exact with ``leaf_stats(G, needs, m, valid=arrival.sum(0))``
    however the workers were bucketed or ordered.
    """
    arrival = arrival.astype(jnp.float32)
    valid = jnp.sum(arrival, axis=0)
    needs_t = tuple(sorted(needs))
    if not needs_t:
        return StreamState({}, valid)
    refs = ops.masked_stat_refs(G, needs_t, valid, axis=axis)

    def body(st, bmask):
        part = leaf_stats(G, needs_t, m, axis=axis, use_pallas=False,
                          valid=valid, rows=bmask, refs=refs)
        return fold_stats(st, part, bmask), None

    state, _ = jax.lax.scan(body, init_stream(needs_t, m), arrival)
    return state


def quorum_met(valid, quorum: int):
    """True once at least ``quorum`` workers' partials have folded in —
    the point selection fires; arrivals past it are dropped."""
    return jnp.sum((valid > 0).astype(jnp.int32)) >= jnp.int32(quorum)


def arrival_active(arrival, quorum: int):
    """[m] f32 quorum mask from [n_buckets, m] arrival buckets: the
    first ``quorum`` workers in arrival order (bucket-major, ties within
    a bucket broken by worker index), dropping everyone later.  0 =
    no quorum (everyone who arrived at all is active)."""
    arrival = arrival.astype(jnp.float32)
    n_buckets, m = arrival.shape
    arrived = jnp.sum(arrival, axis=0) > 0
    if not quorum:
        return arrived.astype(jnp.float32)
    bucket_of = jnp.argmax(arrival, axis=0)            # first (only) bucket
    key = jnp.where(arrived, bucket_of * m + jnp.arange(m),
                    jnp.int32(n_buckets * m + 1) + jnp.arange(m))
    rank = jnp.sum((key[None, :] < key[:, None]).astype(jnp.int32), axis=1)
    return (arrived & (rank < quorum)).astype(jnp.float32)


def stream_aggregate(G, cfg: ByzantineConfig, arrival,
                     spec=None, return_state: bool = False):
    """Local-executor quorum aggregation over a stream of arrival
    buckets: selection fires on the quorum prefix (:func:`arrival_active`
    — at most ``cfg.quorum`` workers), stats fold in bucket by bucket
    (:func:`stream_leaf_stats`), and late arrivals are dropped with
    truthful ``n_selected`` accounting (the returned state's
    ``selected`` never exceeds the quorum)."""
    spec = spec or get_spec(cfg.aggregator)
    m = G.shape[0]
    active = arrival_active(arrival, cfg.quorum)
    if spec.column is not None:
        out = spec.column(G, cfg, m, valid=active, use_pallas=False)
        st = SelectionState(active > 0, active)
        return (out, st) if return_state else out
    state = stream_leaf_stats(G.astype(jnp.float32), spec.stats, m,
                              arrival * active[None, :])
    stats = dict(state.stats)
    stats["valid"] = active
    w, st, _denom = resolve_select(spec, stats, cfg, m)
    Gz = jnp.where(active[:, None] > 0, G.astype(jnp.float32), 0.0)
    agg = ref.masked_mean_det(Gz, w)
    return (agg, st) if return_state else agg


# ---------------------------------------------------------------------------
# aggregator registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AggregatorSpec:
    """Layout-independent description of one aggregation rule."""
    name: str
    stats: frozenset = frozenset()
    select: Optional[Callable] = None   # (stats, cfg, m) -> (w [m], state)
    column: Optional[Callable] = None   # (G [m,cols], cfg, m, **kw) -> [cols]

    def __post_init__(self):
        if (self.select is None) == (self.column is None):
            raise ValueError(
                f"{self.name}: exactly one of select/column must be set")
        unknown = set(self.stats) - set(STAT_NAMES)
        if unknown:
            raise ValueError(f"{self.name}: unknown stats {sorted(unknown)}")


_REGISTRY: dict[str, AggregatorSpec] = {}


def register(spec: AggregatorSpec) -> AggregatorSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> AggregatorSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---- selection rules -------------------------------------------------------
# Every rule handles the elastic case by reading the optional "valid"
# key of the stats dict: byzantine-tolerance counts (krum's f, brsgd's
# top-β) become traced functions of the ACTIVE count, dropped workers'
# rows/columns are pushed to ±inf sentinels so they can never win a
# quantile or a nearest-neighbour window, and returned weights are zero
# on dropped slots (resolve_select re-masks as defense in depth).

def _ones_select(stats, cfg, m):
    valid = stats.get("valid") if isinstance(stats, dict) else None
    if valid is not None:
        return valid.astype(jnp.float32), None
    return jnp.ones((m,), jnp.float32), None


def _brsgd_select_rule(stats, cfg, m):
    valid = stats.get("valid")
    if valid is None:
        st = brsgd_select(stats["scores"], stats["l1"], cfg.beta,
                          cfg.threshold)
    else:
        sel, c1, c2, T = ref.masked_brsgd_select(
            stats["scores"], stats["l1"], cfg.beta, cfg.threshold, valid)
        st = BrSGDState(sel, c1, c2, stats["scores"], stats["l1"], T)
    return st.selected.astype(jnp.float32), st


def _krum_f(cfg, m: int) -> int:
    return cfg.krum_f if cfg.krum_f > 0 else max(1, int(cfg.alpha * m))


def _krum_f_dyn(cfg, na):
    """Traced-count twin of :func:`_krum_f` (same floor/clamp rules)."""
    if cfg.krum_f > 0:
        return jnp.int32(cfg.krum_f)
    return jnp.maximum(1, (cfg.alpha * na.astype(jnp.float32))
                       .astype(jnp.int32))


def _krum_scores(gram, cfg, m: int, valid=None):
    """Krum score_i = Σ of the n-f-2 smallest d²_ij, from the Gram
    matrix (n = m, or the traced active count in an elastic round —
    dropped workers' rows AND columns are +inf, so they neither score
    nor appear in anyone's nearest-neighbour window)."""
    diag = jnp.diagonal(gram)
    d2 = diag[:, None] + diag[None, :] - 2.0 * gram
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf))
    if valid is None:
        n_close = max(1, m - _krum_f(cfg, m) - 2)
        return jnp.sum(jnp.sort(d2, axis=1)[:, :n_close], axis=1)
    v = valid > 0
    na = jnp.sum(v.astype(jnp.int32))
    n_close = jnp.maximum(na - _krum_f_dyn(cfg, na) - 2, 1)
    d2 = jnp.where(v[None, :], d2, jnp.inf)
    d2s = jnp.sort(d2, axis=1)
    keep = jnp.arange(m)[None, :] < n_close
    score = jnp.sum(jnp.where(keep, d2s, 0.0), axis=1)
    return jnp.where(v, score, jnp.inf)


def _krum_select(stats, cfg, m):
    score = _krum_scores(stats["gram"], cfg, m, stats.get("valid"))
    return jax.nn.one_hot(jnp.argmin(score), m, dtype=jnp.float32), None


def _multi_krum_select(stats, cfg, m, n_select: int = 0):
    valid = stats.get("valid")
    score = _krum_scores(stats["gram"], cfg, m, valid)
    if valid is None:
        k = min(m, n_select or max(1, m - _krum_f(cfg, m)))
        best = jnp.argsort(score)[:k]
        return jnp.zeros((m,), jnp.float32).at[best].set(1.0), None
    v = valid > 0
    na = jnp.sum(v.astype(jnp.int32))
    k = jnp.clip(jnp.int32(n_select) if n_select
                 else jnp.maximum(na - _krum_f_dyn(cfg, na), 1),
                 1, jnp.maximum(na, 1))
    order = jnp.argsort(score)                 # dropped (inf) rank last
    w = jnp.zeros((m,), jnp.float32).at[order].set(
        (jnp.arange(m) < k).astype(jnp.float32))
    return w * v.astype(jnp.float32), None


def _geomedian_select(stats, cfg, m, iters: int = GEOMEDIAN_ITERS,
                      eps: float = GEOMEDIAN_EPS):
    """Weiszfeld in weight space: z_t is always a row combination
    Σ w_i g_i / Σ w_i, so distances to it derive from the Gram matrix
    (‖g_i − z‖² = S_ii − 2(Sw)_i/W + wᵀSw/W²) — no per-dimension state
    crosses workers after the one-time stats pass.

    Initialized at the coordinate-wise median (via the ``d2med`` stat) —
    starting from the MEAN under a scale-1e10 attack leaves Weiszfeld in
    the flat far-field where all distances (hence weights) are equal.

    Elastic rounds re-mask the weights EVERY iteration: a dropped slot's
    d2med partial is an exact zero, which would otherwise give it the
    1/eps ceiling weight and let garbage dominate the fixed point.
    """
    valid = stats.get("valid")
    vf = None if valid is None else (valid > 0).astype(jnp.float32)
    S = stats["gram"]
    diag = jnp.diagonal(S)
    w = 1.0 / jnp.maximum(jnp.sqrt(stats["d2med"]), eps)
    if vf is not None:
        w = w * vf

    def step(w, _):
        W = jnp.sum(w)
        Sw = S @ w
        d2 = diag - 2.0 * Sw / W + (w @ Sw) / (W * W)
        w2 = 1.0 / jnp.maximum(jnp.sqrt(jnp.maximum(d2, 0.0)), eps)
        return (w2 if vf is None else w2 * vf), None

    w, _ = jax.lax.scan(step, w, None, length=max(iters - 1, 0))
    return w, None


# ---- per-dimension (column) rules ------------------------------------------

def _median_column(G, cfg, m, valid=None, **kw):
    if valid is not None:
        return ops.cwise_median(G, valid=valid, **kw)
    return ops.cwise_median(G, **kw)


def _trimmed_mean_column(G, cfg, m, valid=None, **kw):
    if valid is not None:
        return ops.trimmed_mean(G, trim_frac=cfg.trim_frac, valid=valid, **kw)
    return ops.trimmed_mean(G, trim_frac=cfg.trim_frac, **kw)


# ---- registry entries (the 7 shipped rules) --------------------------------

register(AggregatorSpec("mean", select=_ones_select))
register(AggregatorSpec("median", column=_median_column))
register(AggregatorSpec("trimmed_mean", column=_trimmed_mean_column))
register(AggregatorSpec("krum", stats=frozenset({"gram"}),
                        select=_krum_select))
register(AggregatorSpec("multi_krum", stats=frozenset({"gram"}),
                        select=_multi_krum_select))
register(AggregatorSpec("geomedian", stats=frozenset({"gram", "d2med"}),
                        select=_geomedian_select))
register(AggregatorSpec("brsgd", stats=frozenset({"scores", "l1"}),
                        select=_brsgd_select_rule))


def spec_with(name: str, **select_kwargs) -> AggregatorSpec:
    """Spec variant with extra keyword arguments bound into its select
    rule (e.g. multi_krum n_select, geomedian iters/eps)."""
    spec = get_spec(name)
    return replace(spec, select=partial(spec.select, **select_kwargs))


def expected_collectives(spec: AggregatorSpec, layout: str, n_leaves: int,
                         fast_paths: bool = True, plan=None) -> dict:
    """Expected per-step counts of the TRANSIENT data-moving collectives
    (all_gather / all_to_all) :func:`aggregate_sharded` emits — the
    engine's half of the ``one-gather-per-leaf`` lint contract
    (``analysis/rules.py`` checks traced steps against this, so a
    double-gather regression in either place fails loudly):

      gather  each leaf is gathered exactly ONCE (phase-1 fused stats,
              or the column rule's view); the weighted combine is
              gather-free.  Stat-free selects (mean) gather nothing.
      a2a     one all_to_all (chunk) + one tiled all_gather (unchunk)
              per leaf; the mean fast path (pmean) skips both.
      local   no collectives at all.
      auto    per-leaf sum over the resolved ``plan`` (an explicit
              per-leaf layout sequence / LayoutPlan, or — when omitted
              — :data:`LAST_PLAN` from the traced region).
    """
    if layout == "local":
        return {"all_gather": 0, "all_to_all": 0}
    mean_fast = spec.name == "mean" and fast_paths
    if layout == "auto":
        plan = LAST_PLAN if plan is None else plan
        if plan is None:
            raise ValueError("layout='auto' needs the resolved plan "
                             "(none traced yet)")
        layouts = tuple(getattr(plan, "layouts", plan))
        if getattr(plan, "fast_path", False) or mean_fast:
            layouts = ()
        want = {"all_gather": 0, "all_to_all": 0}
        for ll in layouts:
            per = expected_collectives(spec, ll, 1, fast_paths)
            for k in want:
                want[k] += per[k]
        return want
    if layout == "a2a":
        n = 0 if mean_fast else n_leaves
        return {"all_gather": n, "all_to_all": n}
    if layout == "gather":
        needs_view = spec.column is not None or bool(spec.stats)
        return {"all_gather": n_leaves if needs_view else 0,
                "all_to_all": 0}
    raise ValueError(f"unknown layout {layout!r}")


# ---------------------------------------------------------------------------
# local executor — single-host G [m, d]
# ---------------------------------------------------------------------------

def _combine_rows(G, w, use_pallas: bool, d_blk: int):
    """Σ_i w_i g_i / Σ_i w_i.  The jnp path accumulates rows in a fixed
    sequential order (ref.masked_mean_det) so results are reproducible
    and mean-degenerate cases are bit-exact; the Pallas path streams G
    through VMEM once."""
    if use_pallas:
        return ops.masked_mean(G, w, use_pallas=True, d_blk=d_blk)
    return ref.masked_mean_det(G.astype(jnp.float32), w)


def aggregate_local(G, cfg: ByzantineConfig, use_pallas: bool | None = None,
                    return_state: bool = False,
                    spec: AggregatorSpec | None = None, d_blk: int = 2048,
                    valid=None):
    """Run one aggregator on the worker-gradient matrix G [m, d] -> [d].

    ``valid`` ([m] 0/1) runs the elastic masked variant: statistics,
    quantiles and the combine cover the active rows only, dropped rows
    contribute exact zeros (DESIGN.md §Elastic).  Masked calls take the
    jnp reference path — the Pallas fast paths assume a full worker set.
    """
    spec = spec or get_spec(cfg.aggregator)
    m = G.shape[0]
    if valid is not None:
        vf = jnp.asarray(valid).astype(jnp.float32)
        if spec.column is not None:
            out = spec.column(G, cfg, m, valid=vf, use_pallas=False)
            st = SelectionState(vf > 0, vf)
            return (out, st) if return_state else out
        stats = dict(leaf_stats(G.astype(jnp.float32), spec.stats, m,
                                use_pallas=False, valid=vf))
        stats["valid"] = vf
        w, st, _denom = resolve_select(spec, stats, cfg, m)
        Gz = jnp.where(vf[:, None] > 0, G.astype(jnp.float32), 0.0)
        agg = ref.masked_mean_det(Gz, w)
        return (agg, st) if return_state else agg

    kw = {} if use_pallas is None else {"use_pallas": use_pallas}
    if spec.column is not None:
        out = spec.column(G, cfg, m, d_blk=d_blk, **kw)
        return (out, None) if return_state else out

    up = ops.default_use_pallas() if use_pallas is None else use_pallas
    if spec.name == "brsgd" and up:
        # fused fast path: pass 1 emits only the [m] partials (no [d]
        # median/mean HBM writes), pass 2 fuses selection + masked mean
        # — G is streamed from HBM exactly twice.
        scores, l1 = ops.brsgd_partials(G, use_pallas=True, d_blk=d_blk)
        agg, _w = ops.brsgd_select_mean(G, scores, l1, cfg.beta,
                                        cfg.threshold, use_pallas=True,
                                        d_blk=d_blk)
        if return_state:
            return agg, brsgd_select(scores, l1, cfg.beta, cfg.threshold)
        return agg

    stats = leaf_stats(G.astype(jnp.float32), spec.stats, m, use_pallas=up)
    w, st = spec.select(stats, cfg, m)
    agg = _combine_rows(G, w, up, d_blk)
    if return_state and st is None:
        st = SelectionState(w > 0, w)
    return (agg, st) if return_state else agg


# ---------------------------------------------------------------------------
# sharded executors — inside shard_map over the worker axes
# ---------------------------------------------------------------------------

def gather_leaf(g, axes, m: int):
    """all_gather one leaf to a worker-major [m, *leaf_shape] f32 view.
    Kept N-D: flattening to [m, cols] would merge tensor-sharded auto
    ('model') dims into one axis and force XLA to un-shard them around
    the reshape.  The collective moves the leaf in its own dtype
    (§Perf); statistics upcast locally."""
    G = jax.lax.optimization_barrier(jax.lax.all_gather(g, axes))
    return G.astype(jnp.float32)


def a2a_chunk(g, axes, m: int):
    """Flatten one leaf, zero-pad to m·⌈D/m⌉, all_to_all over the worker
    axes -> ([m, ⌈D/m⌉] f32 chunk where row r is worker r's values for
    this device's dim range, n_pad_columns).  The wire moves the leaf's
    own dtype; stats upcast locally (§Perf).  Shared with the blocked
    scope (core.blocked), which routes replicated and non-divisible
    leaves through here so they stay on the 1×-memory a2a path."""
    flat = g.reshape(-1)
    D = flat.shape[0]
    c = math.ceil(D / m)
    x = jnp.pad(flat, (0, m * c - D)).reshape(m, c)
    Gc = jax.lax.optimization_barrier(
        jax.lax.all_to_all(x, axes, split_axis=0, concat_axis=0,
                           tiled=False)).astype(jnp.float32)
    return Gc, m * c - D


def unchunk(vec, g, axes):
    """Re-assemble a per-device [⌈D/m⌉] result into the leaf's shape with
    a tiled all_gather, re-replicating in the gradient's own dtype
    (§Perf)."""
    full = jax.lax.all_gather(vec.astype(g.dtype), axes, tiled=True)
    return full[:g.size].reshape(g.shape)


def _model_split(pspec, model_axes) -> int:
    """Number of model shards a leaf is split into under ``pspec`` (1 =
    replicated over the model axes)."""
    if pspec is None or not model_axes:
        return 1
    n = 1
    for entry in pspec:
        names = entry if isinstance(entry, tuple) else (entry,)
        hit = tuple(a for a in names if a in model_axes)
        if hit:
            n *= axis_size(hit)
    return n


def _model_origin(model_axes):
    """1.0 on the devices whose model-axis indices are all zero, else
    0.0 — the mask that keeps model-replicated partials from being
    counted once per model shard in a cross-model psum."""
    ok = jnp.bool_(True)
    for a in model_axes:
        ok = ok & (jax.lax.axis_index((a,)) == 0)
    return ok.astype(jnp.float32)


# the most recent layout="auto" plan resolved by aggregate_sharded —
# trace-time introspection for tests and the lint driver (the plan is
# also logged through the repro.engine logger)
LAST_PLAN = None

_log = logging.getLogger("repro.engine")


def _resolve_plan(spec, m, leaves, layout, plan, elastic,
                  allow_fast_paths):
    """Per-leaf layout list for one aggregation region.  A fixed layout
    broadcasts; "auto" defers to the analytic cost model
    (analysis.costmodel.plan_layouts) over the LOCAL leaf shards —
    deterministic in the shapes, logged, and recorded in LAST_PLAN."""
    global LAST_PLAN
    if layout != "auto":
        return (layout,) * len(leaves)
    if plan is None:
        from ..analysis import costmodel
        plan = costmodel.plan_layouts(
            spec.name, m, [(int(g.size), g.dtype) for g in leaves],
            fast_paths=allow_fast_paths, elastic=elastic)
    layouts = tuple(getattr(plan, "layouts", plan))
    if len(layouts) != len(leaves):
        raise ValueError(f"layout plan covers {len(layouts)} leaves, "
                         f"tree has {len(leaves)}")
    bad = set(layouts) - {"gather", "a2a"}
    if bad:
        raise ValueError(f"layout plan contains unknown layouts {bad}")
    LAST_PLAN = plan
    _log.info("%s", plan.describe() if hasattr(plan, "describe")
              else f"layout plan: {layouts}")
    return layouts


def _worker_origin(axes):
    """1.0 on the devices whose WORKER-axis indices are all zero —
    the mask that keeps worker-replicated gather-leaf stat partials
    from being counted m times when a mixed layout plan closes the
    stats with a worker-axis psum (the a2a leaves' reduction)."""
    ok = jnp.bool_(True)
    for a in axes:
        ok = ok & (jax.lax.axis_index((a,)) == 0)
    return ok.astype(jnp.float32)


def aggregate_sharded(grads, cfg: ByzantineConfig, axes=("data",),
                      layout: str = "gather",
                      spec: AggregatorSpec | None = None,
                      allow_fast_paths: bool = True,
                      flatten_columns: bool = False,
                      model_axes=(), leaf_specs=None, valid=None,
                      plan=None):
    """Aggregate a gradient pytree across the worker mesh axes.

    Must be called inside a FULL-manual shard_map (every mesh axis
    manual): XLA's partial-manual subgroups only support reduce-type
    collectives, so the all_gather/all_to_all paths here cannot coexist
    with auto axes (DESIGN.md §Mesh).  Returns (aggregated pytree —
    identical on every worker, its model shards intact, state | None).
    Any registered aggregator runs in either layout; see the module
    docstring for the layout semantics.

    ``model_axes``/``leaf_specs``: the mesh's tensor-parallel axes and
    each leaf's PartitionSpec.  Leaves sharded over a model axis are
    this device's shard; their statistic partials cover disjoint dim
    ranges across model shards, while model-replicated leaves' partials
    are identical across shards — the executor masks the latter to the
    model-origin devices and closes both with ONE psum over
    worker+model axes (additivity over dimension ranges, the
    ``leaf_stats`` contract).

    ``flatten_columns``: apply gather-layout column rules to N-D leaves
    through a flattened [m, cols] view so the 2-D Pallas kernels stay
    eligible.  Under full-manual the reshape is purely local, so this
    is always safe; it is an opt-in only to keep the N-D jnp path
    testable.

    ``valid`` ([m] 0/1, replicated) runs the elastic round: dropped
    workers' gradients are zeroed on entry (exact zeros — the masking
    contract), statistics/selection cover the active set only, and in
    the a2a layout the validity mask itself RIDES the stats psum as a
    one-hot slot per active worker — the trace-level signal the
    ``masked-psum-validity`` lint rule checks for (DESIGN.md §Elastic).

    ``layout="auto"`` scores gather vs a2a PER LEAF at trace time
    (analysis.costmodel.plan_layouts — big leaves → a2a, tiny leaves →
    gather, stat-free mean → the replicated fast path) and runs the
    mixed plan: one stats psum closes a2a partials over the worker
    axes with gather-leaf partials masked to the worker origin, then
    each leaf combines through its own layout.  ``plan`` overrides the
    model with an explicit per-leaf layout sequence (or LayoutPlan).
    The resolved plan is logged and stored in :data:`LAST_PLAN`.
    """
    if layout not in ("gather", "a2a", "auto"):
        raise ValueError(f"unknown layout {layout!r}")
    spec = spec or get_spec(cfg.aggregator)
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    model_axes = tuple(model_axes)
    m = axis_size(axes)
    leaves, tdef = jax.tree.flatten(grads)
    leaf_layouts = _resolve_plan(spec, m, leaves, layout, plan,
                                 valid is not None, allow_fast_paths)
    any_a2a = "a2a" in leaf_layouts
    if leaf_specs is None:
        spec_leaves = [None] * len(leaves)
    else:
        from jax.sharding import PartitionSpec as P
        # None is a conventional "replicated" spec: keep it as a LEAF
        # (jax.tree would otherwise drop it as an empty subtree and
        # silently misalign every following spec with its gradient)
        spec_leaves = jax.tree.leaves(
            leaf_specs, is_leaf=lambda x: x is None or isinstance(x, P))
        assert len(spec_leaves) == len(leaves), \
            (len(spec_leaves), len(leaves))
    origin = _model_origin(model_axes) if model_axes else None
    elastic = valid is not None
    if elastic:
        vf = jnp.asarray(valid).astype(jnp.float32)
        act_i = vf[jax.lax.axis_index(axes)]
        leaves = [jnp.where(act_i > 0, g, jnp.zeros_like(g))
                  for g in leaves]

    if spec.name == "mean" and allow_fast_paths and not elastic:
        # uniform weights == plain pmean: skip the gather/a2a machinery
        return jax.tree.unflatten(
            tdef, [jax.lax.pmean(g, axes) for g in leaves]), None

    # -- per-dimension rules: no replicated phase at all ----------------
    if spec.column is not None:
        colkw = {"valid": vf, "use_pallas": False} if elastic else {}
        out = []
        for g, ll in zip(leaves, leaf_layouts):
            if ll == "a2a":
                Gc, _pad = a2a_chunk(g, axes, m)
                out.append(unchunk(spec.column(Gc, cfg, m, **colkw),
                                   g, axes))
                continue
            Gv = gather_leaf(g, axes, m)
            if Gv.ndim > 2 and flatten_columns:
                # 2-D view keeps the Pallas column kernels eligible
                # (purely local under full-manual)
                col = spec.column(Gv.reshape(m, -1), cfg, m, **colkw)
            elif Gv.ndim > 2:
                # N-D jnp path (see the blocked-scope column path)
                col = spec.column(Gv, cfg, m, use_pallas=False,
                                  **({"valid": vf} if elastic else {}))
            else:
                col = spec.column(Gv, cfg, m, **colkw)
            out.append(col.astype(g.dtype).reshape(g.shape))
        st = (SelectionState(vf > 0, vf) if elastic else None)
        return jax.tree.unflatten(tdef, out), st

    # -- phase 1: per-leaf stats partials -------------------------------
    # gather layout: each leaf is gathered EXACTLY once, consumed by the
    # fused stats pass, and dropped — nothing m×-sized survives into
    # phase 2, so steady-state transient memory is one gathered leaf
    # instead of the seed's all-leaves cache.  a2a chunks are kept: they
    # are this device's 1/m dim range (1× total), and phase 2 combines
    # them in place.
    stats = zero_stats(spec.stats, m)
    cached, total_pad = [], 0
    # mixed plans: gather-leaf partials are computed from the full
    # gathered view, hence REPLICATED across workers — when a2a leaves
    # force a worker-axis psum they must be masked to the worker origin
    worigin = _worker_origin(axes) if any_a2a else None
    for g, ps, ll in zip(leaves, spec_leaves, leaf_layouts):
        n_split = _model_split(ps, model_axes)
        if ll == "a2a":
            Gv, pad = a2a_chunk(g, axes, m)
            # each model shard pads its own flattened chunk; the psum
            # below sums them, so sharded leaves contribute n_split pads
            total_pad += pad * n_split if n_split > 1 else pad
            cached.append(Gv)
        elif not stats:
            cached.append(None)
            continue        # stat-free select (mean): nothing to gather
        else:
            Gv = gather_leaf(g, axes, m)
            cached.append(None)
        part = leaf_stats(Gv, spec.stats, m,
                          valid=vf if elastic else None)
        if origin is not None and n_split == 1:
            # model-replicated leaf: every model shard would add the
            # same partial — keep only the model-origin copy
            part = {k: v * origin for k, v in part.items()}
        if worigin is not None and ll == "gather":
            part = {k: v * worigin for k, v in part.items()}
        stats = {k: stats[k] + part[k] for k in stats}
    if stats and (any_a2a or model_axes):
        # a2a partials close over the worker axes; model-sharded leaves'
        # partials close over the model axes in the same reduction
        psum_axes = (axes if any_a2a else ()) + model_axes
        if elastic and any_a2a:
            # the validity mask rides the stats psum: each worker
            # contributes its own one-hot slot (masked to the model
            # origin so model shards don't double-count it).  This is
            # the operand the masked-psum-validity lint rule requires —
            # a stats psum without it means some path folded dropped
            # workers' garbage into the selection.
            vpart = jax.nn.one_hot(jax.lax.axis_index(axes), m,
                                   dtype=jnp.float32) * act_i
            stats["valid"] = vpart if origin is None else vpart * origin
        stats = jax.lax.psum(stats, psum_axes)
        stats = pad_correction(stats, total_pad,
                               valid=vf if elastic else None)
    if elastic:
        stats = dict(stats)
        stats.setdefault("valid", vf)

    # -- phase 2: replicated selection + weighted combine ---------------
    w, st, denom = resolve_select(spec, stats, cfg, m)
    out, a2a_idx = [], []
    # gather-free combine: Σᵢ wᵢgᵢ is a psum of each worker's OWN
    # weighted gradient — no leaf is gathered twice and no gathered
    # copy crosses the phase boundary.  The psum runs in f32 (a
    # weighted reduction; 2L wire vs the (m-1)L a re-gather costs).
    wi = (w[jax.lax.axis_index(axes)] if "gather" in leaf_layouts
          else None)
    for i, (g, Gv, ll) in enumerate(zip(leaves, cached, leaf_layouts)):
        if ll == "a2a":
            out.append(unchunk(jnp.tensordot(w, Gv, axes=1) / denom,
                               g, axes))
            a2a_idx.append(i)
        else:
            agg = jax.lax.psum(wi * g.astype(jnp.float32), axes) / denom
            out.append(agg.astype(g.dtype))
    if a2a_idx:
        # stop XLA hoisting the optimizer's f32 upcast back across the
        # all_gather (it would re-widen the wire to f32)
        barred = jax.lax.optimization_barrier(
            tuple(out[i] for i in a2a_idx))
        for i, v in zip(a2a_idx, barred):
            out[i] = v
    return jax.tree.unflatten(tdef, out), st
