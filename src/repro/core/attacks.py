"""Byzantine attack models (paper §5.1).

Gradient-space attacks transform the worker-gradient matrix G [m, d]
given a byzantine mask [m]; the *label-flip* attack lives in the data
pipeline (labels y -> 9 - y on byzantine workers) because it corrupts
data, not gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ByzantineConfig


def byzantine_mask(m: int, alpha: float):
    """First ⌊αm⌋ workers are byzantine (worker identity is arbitrary)."""
    n_byz = int(alpha * m)
    return jnp.arange(m) < n_byz


def gaussian_attack(G, mask, key, cfg: ByzantineConfig):
    """Replace byzantine rows with N(0, std²) noise (paper: std=200)."""
    noise = jax.random.normal(key, G.shape, jnp.float32) * cfg.gaussian_std
    return jnp.where(mask[:, None], noise.astype(G.dtype), G)


def negation_attack(G, mask, key, cfg: ByzantineConfig):
    """Model Negation: byzantine rows = -(sum of correct gradients) * c."""
    honest_sum = jnp.sum(jnp.where(mask[:, None], 0, G.astype(jnp.float32)), axis=0)
    evil = (-cfg.attack_scale * honest_sum).astype(G.dtype)
    return jnp.where(mask[:, None], evil[None], G)


def scale_attack(G, mask, key, cfg: ByzantineConfig):
    """Gradient Scale: byzantine rows scaled by a large constant."""
    return jnp.where(mask[:, None], G * cfg.attack_scale, G)


def sign_flip_attack(G, mask, key, cfg: ByzantineConfig):
    """Extra (not in paper): byzantine rows negate their own gradient."""
    return jnp.where(mask[:, None], -G, G)


def alie_attack(G, mask, key, cfg: ByzantineConfig):
    """ALIE — "A Little Is Enough" (Baruch et al., 2019).

    Byzantine rows move z standard deviations from the honest mean, per
    coordinate — small enough to pass distance filters, coordinated
    enough to bias the aggregate.  z defaults to the classic z_max
    heuristic ~ 1.5 when attack_scale is the (huge) paper default."""
    Gf = G.astype(jnp.float32)
    hon = jnp.where(mask[:, None], jnp.nan, Gf)
    mu = jnp.nanmean(hon, axis=0)
    sd = jnp.nanstd(hon, axis=0)
    z = jnp.float32(cfg.attack_scale if cfg.attack_scale < 100 else 1.5)
    evil = (mu - z * sd).astype(G.dtype)
    return jnp.where(mask[:, None], evil[None], G)


def ipm_attack(G, mask, key, cfg: ByzantineConfig):
    """IPM — Inner-Product Manipulation (Xie et al., 2020).

    Byzantine rows are -eps * mean(honest): for small eps the corrupted
    mean keeps a POSITIVE inner product with the honest direction but is
    shrunk/reversed enough to stall convergence."""
    Gf = G.astype(jnp.float32)
    hon = jnp.where(mask[:, None], jnp.nan, Gf)
    mu = jnp.nanmean(hon, axis=0)
    eps = jnp.float32(cfg.attack_scale if cfg.attack_scale < 100 else 0.5)
    evil = (-eps * mu).astype(G.dtype)
    return jnp.where(mask[:, None], evil[None], G)


GRADIENT_ATTACKS = {
    "gaussian": gaussian_attack,
    "negation": negation_attack,
    "scale": scale_attack,
    "sign_flip": sign_flip_attack,
    "alie": alie_attack,
    "ipm": ipm_attack,
}


def apply_attack(G, key, cfg: ByzantineConfig):
    """Apply cfg.attack to the first ⌊αm⌋ rows of G.  label_flip and
    none are no-ops here (label_flip happens in the data pipeline)."""
    if cfg.attack in ("none", "label_flip") or cfg.alpha <= 0:
        return G
    mask = byzantine_mask(G.shape[0], cfg.alpha)
    return GRADIENT_ATTACKS[cfg.attack](G, mask, key, cfg)
