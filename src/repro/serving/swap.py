"""Hot-swap checkpoint watcher: double-buffered params, flip between
decode steps.

Two host-visible param trees alternate as active/standby.  ``poll()``
(called by the serve loop between decode steps) walks the complete
checkpoints newest-first — cheap directory listing, safe against torn
writes because the trainer's manifest-last protocol
(checkpoint/ckpt.py) makes half-written checkpoints invisible — and on
a new step restores into the STANDBY slot, blocks until the transfer
lands, then flips the active index.  The decode step never observes a
partially-loaded tree, no request is dropped, and because both slots
have identical shapes/dtypes/shardings the jitted decode function
re-runs with zero recompiles (asserted in tests/test_checkpoint.py).

Quarantine (DESIGN.md §Faults): a checkpoint that is *complete* by the
manifest protocol can still fail restore — truncated npz members,
manifest–npz key disagreement, a tree from the wrong model.  ``poll``
catches the restore failure, records the step in ``quarantined`` (never
retried), keeps serving the current live buffer, and falls through to
the next-newest candidate — so one bad publish never takes the server
down or wedges it off newer good checkpoints.
"""
from __future__ import annotations

import time
import zipfile
import zlib
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint import ckpt

# restore failure modes worth quarantining: key/shape mismatches and
# manifest disagreement (ValueError), unreadable/truncated files
# (OSError/EOFError/BadZipFile/zlib), garbage manifests (JSON errors
# are ValueError subclasses).  Anything else propagates.
RESTORE_ERRORS = (ValueError, KeyError, OSError, EOFError,
                  zipfile.BadZipFile, zlib.error)


class HotSwapper:
    def __init__(self, ckpt_dir: str, like, shardings=None,
                 require_initial: bool = True):
        """``like``: param tree of the target shapes/dtypes (manifest
        keys are validated against it on every restore).  ``shardings``:
        optional matching Sharding tree for mesh placement."""
        self.ckpt_dir = ckpt_dir
        self._like = like
        self._shardings = shardings
        self._slots = [None, None]
        self._active = 0
        self.loaded_step: Optional[int] = None
        self.swap_count = 0
        self.swap_stall_s = 0.0
        self.last_stall_s = 0.0
        self.quarantined: dict = {}            # step -> failure reason
        self._last_load_t = time.perf_counter()
        if not self.poll() and require_initial:
            raise FileNotFoundError(
                f"no restorable checkpoint under {ckpt_dir}")

    def params(self):
        return self._slots[self._active]

    def staleness_s(self) -> float:
        """Seconds since params last advanced — the stale-swap-source
        detection signal the serve loop exports as a gauge."""
        return time.perf_counter() - self._last_load_t

    def poll(self) -> bool:
        """Load the newest restorable checkpoint if one newer than the
        live buffer exists.  Returns True when the active params
        flipped; quarantined steps are skipped forever."""
        for step in sorted(ckpt.steps(self.ckpt_dir), reverse=True):
            if self.loaded_step is not None and step <= self.loaded_step:
                break
            if step in self.quarantined:
                continue
            t0 = time.perf_counter()
            try:
                tree, step = ckpt.restore(self.ckpt_dir, self._like,
                                          step=step,
                                          shardings=self._shardings)
            except RESTORE_ERRORS as e:
                self.quarantined[step] = f"{type(e).__name__}: {e}"
                continue                       # fall back to next-newest
            if self._shardings is None:
                tree = jax.tree.map(jnp.asarray, tree)
            jax.block_until_ready(tree)
            standby = 1 - self._active
            self._slots[standby] = tree
            self._active = standby
            stall = time.perf_counter() - t0
            if self.loaded_step is not None:   # first load isn't a swap
                self.swap_count += 1
                self.swap_stall_s += stall
            self.last_stall_s = stall
            self.loaded_step = step
            self._last_load_t = time.perf_counter()
            return True
        return False
