"""Hot-swap checkpoint watcher: double-buffered params, flip between
decode steps.

Two host-visible param trees alternate as active/standby.  ``poll()``
(called by the serve loop between decode steps) checks
``ckpt.latest_step`` — cheap directory listing, safe against torn writes
because the trainer's manifest-last protocol (checkpoint/ckpt.py) makes
half-written checkpoints invisible — and on a new step restores into the
STANDBY slot, blocks until the transfer lands, then flips the active
index.  The decode step never observes a partially-loaded tree, no
request is dropped, and because both slots have identical
shapes/dtypes/shardings the jitted decode function re-runs with zero
recompiles (asserted in tests/test_checkpoint.py).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint import ckpt


class HotSwapper:
    def __init__(self, ckpt_dir: str, like, shardings=None,
                 require_initial: bool = True):
        """``like``: param tree of the target shapes/dtypes (manifest
        keys are validated against it on every restore).  ``shardings``:
        optional matching Sharding tree for mesh placement."""
        self.ckpt_dir = ckpt_dir
        self._like = like
        self._shardings = shardings
        self._slots = [None, None]
        self._active = 0
        self.loaded_step: Optional[int] = None
        self.swap_count = 0
        self.swap_stall_s = 0.0
        self.last_stall_s = 0.0
        if not self.poll() and require_initial:
            raise FileNotFoundError(
                f"no complete checkpoint under {ckpt_dir}")

    def params(self):
        return self._slots[self._active]

    def poll(self) -> bool:
        """Load the newest complete checkpoint if it advanced.  Returns
        True when the active params flipped."""
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None or step == self.loaded_step:
            return False
        t0 = time.perf_counter()
        tree, step = ckpt.restore(self.ckpt_dir, self._like, step=step,
                                  shardings=self._shardings)
        if self._shardings is None:
            tree = jax.tree.map(jnp.asarray, tree)
        jax.block_until_ready(tree)
        standby = 1 - self._active
        self._slots[standby] = tree
        self._active = standby
        stall = time.perf_counter() - t0
        if self.loaded_step is not None:       # first load isn't a swap
            self.swap_count += 1
            self.swap_stall_s += stall
        self.last_stall_s = stall
        self.loaded_step = step
        return True
