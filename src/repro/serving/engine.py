"""Serving path: batched prefill + single-token decode steps.

``build_serve_step`` returns jitted functions with explicit shardings:
  * params: tensor-parallel over 'model' (+FSDP over workers for >20B
    so 236B fits 512 x 16GB)
  * prefill: batch over the worker axes
  * decode:  batch over workers; KV/state cache batch over workers —
    except ``global_batch == 1`` (long_500k) where the cache SEQUENCE
    dim shards over 'data' instead (flash-decoding style: XLA emits the
    partial-softmax combine collectives).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..launch.mesh import n_workers, worker_axes
from ..models import params as PM
from ..models import transformer as TF

SERVE_FSDP_PARAMS = 20e9


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, mesh,
                shard_seq: bool) -> dict:
    """PartitionSpec tree matching models.transformer.cache_defs."""
    waxes = worker_axes(mesh)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)
    nw = n_workers(mesh)

    def spec_of(shape_axes):
        shape, axes = shape_axes
        entries = []
        for s, a in zip(shape, axes):
            if a == "batch" and not shard_seq and s % nw == 0 and s >= nw:
                entries.append(wspec)
            elif a == "seq" and shard_seq and s % nw == 0 and s >= nw:
                entries.append(wspec)
            elif a in ("kv", "heads", "inner") and s % n_model == 0 and s >= n_model:
                entries.append("model")
            else:
                entries.append(None)
        return P(*entries)

    defs = TF.cache_defs(cfg, batch, seq_len)
    return jax.tree.map(spec_of, defs,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and isinstance(x[0], tuple))


class ServeBundle(NamedTuple):
    prefill_fn: object          # (params, batch) -> logits
    decode_fn: object           # (params, cache, token, pos) -> (logits, cache)
    param_specs: object
    cache_spec_tree: object
    batch_spec: P
    # fused cache-writing prefill: (params, batch, cache) ->
    # (logits, cache') — appended last so positional users keep working
    prefill_cache_fn: object = None


def build_serve_step(cfg: ModelConfig, shape: InputShape, mesh) -> ServeBundle:
    defs = TF.param_defs(cfg)
    n = PM.count_params(defs)
    fsdp = n > SERVE_FSDP_PARAMS
    pspecs = PM.pspec_tree(defs, mesh, fsdp=fsdp)
    waxes = worker_axes(mesh)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    nw = n_workers(mesh)
    shard_seq = shape.global_batch < nw            # long_500k: B=1
    bspec = P(None) if shard_seq else P(wspec)
    cspecs = cache_specs(cfg, shape.global_batch, shape.seq_len, mesh, shard_seq)

    def prefill(params, batch):
        logits, _ = TF.forward(cfg, params, batch["tokens"],
                               batch.get("prefix_embed"))
        return logits

    def prefill_cache(params, batch, cache):
        return TF.prefill_cache(cfg, params, batch["tokens"], cache,
                                batch.get("prefix_embed"))

    def decode(params, cache, token, pos):
        return TF.decode_step(cfg, params, cache, token, pos)

    return ServeBundle(jax.jit(prefill), jax.jit(decode, donate_argnums=(1,)),
                       pspecs, cspecs, bspec,
                       jax.jit(prefill_cache, donate_argnums=(2,)))
