"""Slot-paged decode cache for continuous batching.

The whole serve fleet shares ONE cache tree shaped ``[max_batch]`` on
the batch axis (the pad-to-max idiom from the elastic trainer, DESIGN.md
§Elastic / §Serve).  A request's "page" is its batch slot: the
``BlockTable`` maps request-id → slot, and ``SlotCache.insert`` scatters
a freshly-prefilled batch=1 cache slice into the big buffers with a
TRACED slot index, so admissions and evictions never recompile anything.
Attention/wkv6 kernels are untouched — paging is slot-granular, not
token-granular; each slot owns a fixed ``max_len`` (or ``window``) strip
of every cache leaf.

The batch axis position varies per leaf (axis 1 for attention/rwkv
stacks, axis 2 for the hybrid mamba sub-stacks) — ``batch_axes`` derives
it from the logical axis names in ``TF.cache_defs`` rather than
hard-coding layouts, so new cache families inherit slot paging for free.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as TF

_is_def = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)


def batch_axes(cfg: ModelConfig, batch: int, seq_len: int):
    """Tree of ints: index of the 'batch' axis in every cache leaf."""
    defs = TF.cache_defs(cfg, batch, seq_len)
    return jax.tree.map(lambda sd: sd[1].index("batch"), defs, is_leaf=_is_def)


class BlockTable:
    """request-id → slot map over ``max_batch`` pages; O(1) alloc/free."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self._free = list(range(max_batch - 1, -1, -1))
        self._slot_of: dict = {}

    def __len__(self):
        return len(self._slot_of)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc(self, rid) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self._slot_of[rid] = slot
        return slot

    def slot(self, rid) -> int:
        return self._slot_of[rid]

    def free(self, rid) -> int:
        slot = self._slot_of.pop(rid)
        self._free.append(slot)
        return slot


class SlotCache:
    """The shared ``[max_batch]`` cache buffers + the jitted slot insert.

    ``shardings``: optional PartitionSpec tree (``engine.cache_specs``)
    to place the buffers on a mesh; insertion shardings follow from the
    donated output.
    """

    def __init__(self, cfg: ModelConfig, max_batch: int, max_len: int,
                 dtype=jnp.bfloat16, mesh=None, shardings=None):
        self.cfg, self.max_batch, self.max_len = cfg, max_batch, max_len
        self.dtype = dtype
        self.bufs = TF.init_cache(cfg, max_batch, max_len, dtype)
        self.axes = batch_axes(cfg, max_batch, max_len)
        if mesh is not None and shardings is not None:
            from jax.sharding import NamedSharding
            self.bufs = jax.tree.map(
                lambda b, s: jax.device_put(b, NamedSharding(mesh, s)),
                self.bufs, shardings)

        def ins(big, small, i):
            return jax.tree.map(
                lambda b, s, ax: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), i, axis=ax),
                big, small, self.axes)

        self._insert = jax.jit(ins, donate_argnums=(0,))

    def insert(self, small, slot: int):
        """Scatter a batch=1 cache slice into ``slot`` (traced index)."""
        self.bufs = self._insert(self.bufs, small, jnp.int32(slot))

    def insert_compiles(self) -> int:
        return self._insert._cache_size()
