"""Continuous-batching scheduler: request queue → slot map → ONE jitted
decode step over a fixed ``[max_batch]`` slot array.

The same pad-to-max + traced-validity-mask idiom the elastic trainer
uses for variable worker counts (DESIGN.md §Elastic) applied to serving:
the decode step is compiled ONCE for ``[max_batch]`` slots; per-slot
positions (``decode_step``'s ``[B]`` pos vector) and a traced live mask
let requests join and finish at any step with ZERO recompiles.  Dead
slots keep computing (they re-write their own last cache entry — a
no-op) and their outputs are masked off on the host; admission scatters
a freshly-prefilled batch=1 cache slice into a free slot with a traced
slot index (serving/cache.py).

Prefill policy: attention-only, non-windowed configs pad prompts to
power-of-two buckets (one compile per bucket; right-pad garbage is
overwritten-before-read under the ``idx <= pos`` validity mask).
Recurrent (rwkv/mamba) or windowed configs prefill at EXACT length —
padding would corrupt the carried O(1) state / ring buffer — costing one
compile per distinct prompt length (DESIGN.md §Serve).

MoE caveat: routing is cross-batch, so dead slots consume expert
capacity in batched decode; at serve batch sizes this only perturbs
capacity-dropped tokens (exact parity tests use dense configs).

Stalls + timeouts (DESIGN.md §Faults): a slot can stop making progress
(wedged device — injected by the ``slot_stall`` fault via
``inject_stall``).  Stalled slots are masked out of the live decode set
(the same traced mask, so no recompile); a ``request_timeout`` > 0 arms
the watchdog: a slot that makes no progress for that many scheduler
ticks is torn down and its request REQUEUED from scratch at the front
of the queue (generated tokens discarded — the cache slot may be the
wedged resource), counted in ``metrics.requeues``.  Every request
therefore eventually completes or requeues-then-completes; nothing is
silently dropped.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import transformer as TF
from .cache import BlockTable, SlotCache
from .swap import HotSwapper
from .telemetry import ServeMetrics


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class ServeLoop:
    def __init__(self, cfg: ModelConfig, max_batch: int, max_len: int,
                 params=None, swapper: Optional[HotSwapper] = None,
                 dtype=None, metrics: Optional[ServeMetrics] = None,
                 mesh=None, cache_shardings=None,
                 request_timeout: int = 0):
        if (params is None) == (swapper is None):
            raise ValueError("pass exactly one of params / swapper")
        self.cfg, self.max_batch, self.max_len = cfg, max_batch, max_len
        self.swapper = swapper
        self._params = params
        self.metrics = metrics or ServeMetrics()
        # per-request watchdog: 0 = off; N = requeue a slot's request
        # after N scheduler ticks without decode progress
        self.request_timeout = request_timeout
        self.ticks = 0
        self._last_progress = np.zeros((max_batch,), np.int64)
        self._stalled_until = np.zeros((max_batch,), np.int64)
        dtype = dtype or (jnp.float32 if cfg.dtype == "float32"
                          else jnp.bfloat16)
        self.cache = SlotCache(cfg, max_batch, max_len, dtype, mesh,
                               cache_shardings)
        self.table = BlockTable(max_batch)
        self.queue: deque = deque()
        self.done: dict = {}
        self.steps = 0
        self._next_rid = 0
        # host-side slot state (tiny [B] vectors, shipped every step)
        self._tok = np.zeros((max_batch, 1), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self._remaining = np.zeros((max_batch,), np.int32)
        self._req_of_slot: list = [None] * max_batch
        seg_kinds = {s.kind for s in TF.segments(cfg)}
        self._bucket_ok = (not cfg.attention.window
                           and not (seg_kinds & {"rwkv", "hybrid"}))

        def prefill(params, tokens, last):
            small = TF.init_cache(cfg, 1, max_len, dtype)
            logits, small = TF.prefill_cache(cfg, params, tokens, small)
            first = jnp.argmax(logits[0, last], -1).astype(jnp.int32)
            return small, first

        def step(params, cache, tok, pos, live):
            logits, cache = TF.decode_step(cfg, params, cache, tok, pos)
            nxt = jnp.argmax(logits.reshape(max_batch, -1),
                             axis=-1).astype(jnp.int32)
            tok2 = jnp.where(live[:, None], nxt[:, None], tok)
            pos2 = jnp.where(live, jnp.minimum(pos + 1, max_len - 1), pos)
            return cache, tok2, pos2, nxt

        self._prefill = jax.jit(prefill)
        self._step = jax.jit(step, donate_argnums=(1,))

    # -- compile counters (zero-recompile assertions ride on these) ----
    def decode_compiles(self) -> int:
        return self._step._cache_size()

    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    def params(self):
        return self.swapper.params() if self.swapper else self._params

    # -- request lifecycle ---------------------------------------------
    def submit(self, prompt, max_new: int, rid=None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        S = prompt.shape[0]
        if S >= self.max_len:
            raise ValueError(f"prompt length {S} >= max_len {self.max_len}")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.append(Request(rid, prompt,
                                  min(max_new, self.max_len - S)))
        return rid

    def _admit(self):
        params = self.params()
        while self.queue and self.table.free_slots:
            req = self.queue.popleft()
            slot = self.table.alloc(req.rid)
            S = req.prompt.shape[0]
            Sb = min(_next_pow2(S), self.max_len - 1) if self._bucket_ok else S
            toks = np.zeros((1, Sb), np.int32)
            toks[0, :S] = req.prompt
            small, first = self._prefill(params, jnp.asarray(toks),
                                         jnp.int32(S - 1))
            self.cache.insert(small, slot)
            self.metrics.prefills += 1
            first = int(first)
            req.tokens.append(first)
            self._req_of_slot[slot] = req
            self._tok[slot, 0] = first
            self._pos[slot] = S
            self._remaining[slot] = req.max_new - 1
            self._last_progress[slot] = self.ticks
            if req.max_new <= 1:
                self._finish(slot)

    def _finish(self, slot: int):
        req = self._req_of_slot[slot]
        self._req_of_slot[slot] = None
        self._remaining[slot] = 0
        self.table.free(req.rid)
        self.done[req.rid] = np.asarray(req.tokens, np.int32)
        self.metrics.completed += 1

    # -- fault surface + watchdog --------------------------------------
    def inject_stall(self, slot: int, ticks: int) -> None:
        """Fault-injection hook (faults ``slot_stall``, benchmarks/
        chaos.py): mask ``slot`` out of the live decode set for the
        next ``ticks`` scheduler ticks — the slot stops making
        progress, as a wedged device would."""
        self._stalled_until[slot] = self.ticks + ticks

    def _requeue(self, slot: int) -> None:
        """Tear down a timed-out slot and restart its request from
        scratch at the queue front (tokens discarded — the slot, and
        anything cached in it, may be the wedged resource)."""
        req = self._req_of_slot[slot]
        self._req_of_slot[slot] = None
        self._remaining[slot] = 0
        self.table.free(req.rid)
        req.tokens = []
        self.queue.appendleft(req)
        self.metrics.requeues += 1

    def _check_timeouts(self) -> None:
        if not self.request_timeout:
            return
        for slot in range(self.max_batch):
            if (self._req_of_slot[slot] is not None
                    and self.ticks - self._last_progress[slot]
                    > self.request_timeout):
                self._requeue(slot)

    # -- main loop ------------------------------------------------------
    def run(self, on_step: Optional[Callable] = None) -> dict:
        """Drain the queue; returns {rid: generated tokens [max_new]}.

        ``on_step(loop, step_idx)`` fires after every decode step —
        hooks for tests/demos (e.g. publish a checkpoint mid-stream to
        force a hot swap under live decode).
        """
        idle = 0
        while self.queue or len(self.table):
            self.ticks += 1
            self._admit()
            if self.swapper is not None:
                if self.swapper.poll():
                    self.metrics.observe_swap(self.swapper.last_stall_s)
                self.metrics.gauge("ckpt_staleness_s",
                                   self.swapper.staleness_s())
                self.metrics.gauge("quarantined_ckpts",
                                   len(self.swapper.quarantined))
            self.metrics.queue_depth = len(self.queue)
            self.metrics.active_slots = len(self.table)
            self._check_timeouts()
            live_np = ((self._remaining > 0)
                       & (self._stalled_until <= self.ticks))
            if not live_np.any():
                # nothing can decode: stalled slots (or everything
                # finished at admit).  Ticks keep advancing so stalls
                # expire and the watchdog still fires; the idle cap
                # turns a stall with no timeout into a loud error
                # instead of a silent spin.
                idle += 1
                if idle > 100_000:
                    raise RuntimeError(
                        "serve loop wedged: no decode progress for "
                        "100000 ticks (stalled slots and no "
                        "request_timeout?)")
                continue
            idle = 0
            t0 = time.perf_counter()
            bufs, tok, pos, nxt = self._step(
                self.params(), self.cache.bufs, jnp.asarray(self._tok),
                jnp.asarray(self._pos), jnp.asarray(live_np))
            nxt = np.asarray(nxt)
            dt = time.perf_counter() - t0
            self.cache.bufs = bufs
            self._tok = np.array(tok)      # copy: host state stays writable
            self._pos = np.array(pos)
            self.steps += 1
            self._last_progress[live_np] = self.ticks
            n_live = int(live_np.sum())
            self.metrics.observe_decode(dt, n_live)
            for slot in np.nonzero(live_np)[0]:
                req = self._req_of_slot[slot]
                req.tokens.append(int(nxt[slot]))
                self._remaining[slot] -= 1
                if self._remaining[slot] <= 0:
                    self._finish(slot)
            if on_step is not None:
                on_step(self, self.steps)
        return self.done
