"""Robustness telemetry channel: train loop → JSONL → serve /metrics.

Training appends one row per logged step to ``telemetry.jsonl`` beside
its checkpoints (same directory the hot-swap watcher polls), so the
server can surface LIVE what the aggregation layer saw when the weights
it is currently serving were produced — selection rate vs the
``alpha·m`` bound (Yin et al. 1803.01498), active-worker count, quorum.

Row schema (DESIGN.md §Serve; append-only — add keys, never rename):
    {"step", "gnorm", "n_selected", "n_selected_min", "n_active",
     "quorum"}

``ServeMetrics`` collects the serving-side counters (per-token latency,
queue depth, swap count/stall) and renders both sides as a
``/metrics``-style text dump.  Add-a-counter recipe: call
``metrics.gauge(name, value)`` — it lands in ``snapshot()`` and
``render()`` with the ``repro_serve_`` prefix, nothing else to wire.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

TELEMETRY_FILE = "telemetry.jsonl"
TRAIN_KEYS = ("step", "gnorm", "n_selected", "n_selected_min", "n_active",
              "quorum")


def append_row(ckpt_dir: str, row: dict) -> None:
    """Append one training telemetry row (validates the schema keys).

    Each row is flushed AND fsynced: a host crash mid-run loses at most
    the in-flight row (which the torn-tail-tolerant ``read_rows``
    skips), never buffered complete rows — the recovery supervisor's
    post-mortem reads ride on this (DESIGN.md §Faults)."""
    missing = [k for k in TRAIN_KEYS if k not in row]
    if missing:
        raise ValueError(f"telemetry row missing keys {missing}")
    os.makedirs(ckpt_dir, exist_ok=True)
    with open(os.path.join(ckpt_dir, TELEMETRY_FILE), "a") as f:
        f.write(json.dumps({k: row[k] for k in row}) + "\n")
        f.flush()
        os.fsync(f.fileno())


def read_rows(ckpt_dir: str) -> list:
    path = os.path.join(ckpt_dir, TELEMETRY_FILE)
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue      # torn tail line from a concurrent writer
    return rows


def latest_row(ckpt_dir: str) -> Optional[dict]:
    rows = read_rows(ckpt_dir)
    return rows[-1] if rows else None


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


class ServeMetrics:
    """Serving-side counters.  Per-token latency is the wall time of the
    decode step that emitted the token (every live slot emits exactly one
    token per step, so step samples ARE per-token samples)."""

    def __init__(self):
        self.step_lat_s: list = []       # one sample per decode step
        self.tokens = 0
        self.queue_depth = 0
        self.active_slots = 0
        self.completed = 0
        self.swaps = 0
        self.swap_stall_s = 0.0
        self.prefills = 0
        self.requeues = 0         # watchdog-restarted requests (§Faults)
        self._gauges: dict = {}
        self._t0 = time.perf_counter()

    def observe_decode(self, dt_s: float, n_live: int) -> None:
        self.step_lat_s.append(dt_s)
        self.tokens += n_live

    def observe_swap(self, stall_s: float) -> None:
        self.swaps += 1
        self.swap_stall_s += stall_s

    def gauge(self, name: str, value) -> None:
        self._gauges[name] = value

    def snapshot(self, train_row: Optional[dict] = None) -> dict:
        lat = sorted(self.step_lat_s)
        wall = max(time.perf_counter() - self._t0, 1e-9)
        out = {
            "latency_p50_ms": _percentile(lat, 0.50) * 1e3,
            "latency_p99_ms": _percentile(lat, 0.99) * 1e3,
            "tokens_per_s": self.tokens / wall,
            "tokens_total": self.tokens,
            "queue_depth": self.queue_depth,
            "active_slots": self.active_slots,
            "requests_completed": self.completed,
            "requests_requeued": self.requeues,
            "prefills": self.prefills,
            "swaps": self.swaps,
            "swap_stall_ms": self.swap_stall_s * 1e3,
            **self._gauges,
        }
        if train_row:
            out["train"] = {k: train_row[k] for k in TRAIN_KEYS
                            if k in train_row}
        return out

    def render(self, train_row: Optional[dict] = None) -> str:
        """/metrics-style text: one ``name value`` line per counter."""
        snap = self.snapshot(train_row)
        train = snap.pop("train", None)
        lines = [f"repro_serve_{k} {v:.6g}" if isinstance(v, float)
                 else f"repro_serve_{k} {v}" for k, v in snap.items()]
        if train:
            lines += [f"repro_train_{k} {v:.6g}" if isinstance(v, float)
                      else f"repro_train_{k} {v}" for k, v in train.items()]
        return "\n".join(lines) + "\n"
