from .engine import ServeBundle, build_serve_step, cache_specs
from .cache import BlockTable, SlotCache, batch_axes
from .scheduler import Request, ServeLoop
from .swap import HotSwapper
from .telemetry import ServeMetrics, append_row, latest_row, read_rows
