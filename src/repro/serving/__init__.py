from .engine import ServeBundle, build_serve_step, cache_specs
