"""Synthetic data sources (the container is offline).

* ``TokenStream`` — deterministic pseudo-corpus of token sequences with
  Zipf-ish marginals and a learnable bigram structure, so small LMs
  show decreasing loss within a few hundred steps.
* ``fmnist_like`` — FashionMNIST-geometry image classification set:
  10 classes, 28x28, class-conditional low-rank Gaussian patterns.
  Learnable by LeNet to high accuracy; used for the paper repro.
* label-flip corruption (paper's "Label Shift" attack: y -> 9 - y) is a
  data-pipeline transform applied to byzantine workers' shards.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenStream:
    """Deterministic synthetic LM corpus.

    Sequences follow a noisy bigram chain: next ~ (cur * A + 1) mod V
    with probability q, else uniform — so cross-entropy has a learnable
    floor well below log(V).
    """

    def __init__(self, vocab: int, seed: int = 0, q: float = 0.8, mult: int = 31):
        self.vocab = int(vocab)
        self.seed = seed
        self.q = q
        self.mult = mult

    def batch(self, step: int, batch: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        toks = np.empty((batch, seq_len), np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        toks[:, 0] = cur
        for t in range(1, seq_len):
            follow = rng.random(batch) < self.q
            nxt = (cur * self.mult + 1) % self.vocab
            rand = rng.integers(0, self.vocab, size=batch)
            cur = np.where(follow, nxt, rand)
            toks[:, t] = cur
        return toks


def fmnist_like(n: int, seed: int = 0, image_size: int = 28, n_classes: int = 10,
                template_seed: int = 1234):
    """Class-conditional synthetic image set: (images [n,28,28,1] in
    [0,1], labels [n]).  Each class has a fixed random low-frequency
    template + per-sample noise.  The class templates come from
    ``template_seed`` (fixed by default) so train/test splits drawn with
    different ``seed`` values share one underlying distribution."""
    trng = np.random.default_rng(template_seed)
    rng = np.random.default_rng(seed)
    # low-frequency class templates: random 7x7 upsampled to 28x28
    base = trng.normal(0, 1, size=(n_classes, 7, 7))
    templates = np.kron(base, np.ones((4, 4)))               # [C,28,28]
    labels = rng.integers(0, n_classes, size=n)
    imgs = templates[labels] + rng.normal(0, 0.7, size=(n, image_size, image_size))
    imgs = 1.0 / (1.0 + np.exp(-imgs))                       # squash to (0,1)
    return imgs[..., None].astype(np.float32), labels.astype(np.int32)


