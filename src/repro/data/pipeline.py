"""Per-worker sharded batch pipeline.

Produces batches with a leading worker axis [m, b, ...] — the layout
both the vmap simulation path and the shard_map distributed path
consume (the distributed path shards the worker axis over the mesh's
worker axes).  Byzantine *data* corruption (label flip) happens here,
on the shards of the byzantine workers, exactly as in the paper where
byzantine machines "compute gradients on these data".
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..configs.base import ByzantineConfig, InputShape, ModelConfig
from .synthetic import TokenStream, flip_labels, fmnist_like


class LMWorkerPipeline:
    """Token batches [m, b, S] for LM training."""

    def __init__(self, cfg: ModelConfig, n_workers: int, batch_per_worker: int,
                 seq_len: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None):
        self.cfg = cfg
        self.m = n_workers
        self.b = batch_per_worker
        self.seq = seq_len
        self.stream = TokenStream(cfg.vocab, seed=seed)
        self.byz = byz

    def batch(self, step: int) -> dict:
        toks = self.stream.batch(step, self.m * self.b, self.seq)
        toks = toks.reshape(self.m, self.b, self.seq)
        if (self.byz is not None and self.byz.attack == "label_flip"
                and self.byz.alpha > 0):
            n_byz = int(self.byz.alpha * self.m)
            # corrupt the byzantine workers' target stream: reverse tokens
            toks[:n_byz] = self.cfg.vocab - 1 - toks[:n_byz]
        out = {"tokens": toks}
        if self.cfg.n_prefix_tokens:
            rng = np.random.default_rng(step)
            out["prefix_embed"] = rng.normal(
                0, 0.02, size=(self.m, self.b, self.cfg.n_prefix_tokens,
                               self.cfg.d_model)).astype(np.float32)
        return out


class ImageWorkerPipeline:
    """FashionMNIST-like shards for the LeNet repro: each worker owns n
    samples (paper: i.i.d. per-worker datasets); byzantine workers' labels
    are flipped when the attack is label_flip."""

    def __init__(self, n_workers: int, n_per_worker: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None, n_classes: int = 10):
        self.m, self.n = n_workers, n_per_worker
        imgs, labels = fmnist_like(n_workers * n_per_worker, seed=seed)
        self.images = imgs.reshape(n_workers, n_per_worker, *imgs.shape[1:])
        labels = labels.reshape(n_workers, n_per_worker)
        if byz is not None and byz.attack == "label_flip" and byz.alpha > 0:
            n_byz = int(byz.alpha * n_workers)
            labels[:n_byz] = flip_labels(labels[:n_byz], n_classes)
        self.labels = labels
        self.test_images, self.test_labels = fmnist_like(2048, seed=seed + 777)

    def batch(self, step: int, batch_per_worker: int) -> dict:
        rng = np.random.default_rng(step)
        idx = rng.integers(0, self.n, size=(self.m, batch_per_worker))
        take = np.take_along_axis
        return {
            "images": np.stack([self.images[w, idx[w]] for w in range(self.m)]),
            "labels": np.stack([self.labels[w, idx[w]] for w in range(self.m)]),
        }
