"""Per-worker sharded batch pipeline.

Produces batches with a leading worker axis [m, b, ...] — the layout
both the vmap simulation path and the shard_map distributed path
consume (the distributed path shards the worker axis over the mesh's
worker axes).  Byzantine *data* corruption happens here, on the shards
of the byzantine workers, exactly as in the paper where byzantine
machines "compute gradients on these data": any data-scope
``AttackSpec`` registered in :mod:`..core.threat` (label_flip ships)
applies its ``corrupt_labels`` rule to the workers selected by the
config's membership policy (``threat.data_membership``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..configs.base import ByzantineConfig, InputShape, ModelConfig
from ..core import threat
from .synthetic import TokenStream, fmnist_like


def data_attack_spec(byz: Optional[ByzantineConfig]):
    """The active data-scope AttackSpec, or None (gradient-scope and
    attack-free configs corrupt nothing here)."""
    if byz is None or byz.attack == "none" or byz.alpha <= 0:
        return None
    spec = threat.get_spec(byz.attack)
    return spec if spec.scope == "data" else None


class LMWorkerPipeline:
    """Token batches [m, b, S] for LM training."""

    def __init__(self, cfg: ModelConfig, n_workers: int, batch_per_worker: int,
                 seq_len: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None):
        self.cfg = cfg
        self.m = n_workers
        self.b = batch_per_worker
        self.seq = seq_len
        self.stream = TokenStream(cfg.vocab, seed=seed)
        self.byz = byz

    def batch(self, step: int) -> dict:
        toks = self.stream.batch(step, self.m * self.b, self.seq)
        toks = toks.reshape(self.m, self.b, self.seq)
        spec = data_attack_spec(self.byz)
        if spec is not None:
            # corrupt the byzantine workers' target stream
            mask = threat.data_membership(self.byz, self.m, step)
            toks[mask] = spec.corrupt_labels(toks[mask], self.cfg.vocab)
        out = {"tokens": toks}
        if self.cfg.n_prefix_tokens:
            rng = np.random.default_rng(step)
            out["prefix_embed"] = rng.normal(
                0, 0.02, size=(self.m, self.b, self.cfg.n_prefix_tokens,
                               self.cfg.d_model)).astype(np.float32)
        return out


class ImageWorkerPipeline:
    """FashionMNIST-like shards for the LeNet repro: each worker owns n
    samples (paper: i.i.d. per-worker datasets); byzantine workers'
    labels are corrupted by any registered data-scope attack.  The
    dataset stays CLEAN in storage and corruption is applied per
    ``batch(step)`` from a step-keyed membership mask — exactly like
    the LM pipeline — so the ``resample`` policy draws a fresh
    byzantine set every step instead of degenerating to the step-0
    draw (the previous behaviour: the dataset was corrupted once at
    construction)."""

    def __init__(self, n_workers: int, n_per_worker: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None, n_classes: int = 10):
        self.m, self.n = n_workers, n_per_worker
        self.byz, self.n_classes = byz, n_classes
        imgs, labels = fmnist_like(n_workers * n_per_worker, seed=seed)
        self.images = imgs.reshape(n_workers, n_per_worker, *imgs.shape[1:])
        self.labels = labels.reshape(n_workers, n_per_worker)
        self.test_images, self.test_labels = fmnist_like(2048, seed=seed + 777)

    def batch(self, step: int, batch_per_worker: int) -> dict:
        rng = np.random.default_rng(step)
        idx = rng.integers(0, self.n, size=(self.m, batch_per_worker))
        labels = np.stack([self.labels[w, idx[w]] for w in range(self.m)])
        spec = data_attack_spec(self.byz)
        if spec is not None:
            mask = threat.data_membership(self.byz, self.m, step)
            labels[mask] = spec.corrupt_labels(labels[mask], self.n_classes)
        return {
            "images": np.stack([self.images[w, idx[w]] for w in range(self.m)]),
            "labels": labels,
        }
