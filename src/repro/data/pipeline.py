"""Per-worker sharded batch pipeline.

Produces batches with a leading worker axis [m, b, ...] — the layout
both the vmap simulation path and the shard_map distributed path
consume (the distributed path shards the worker axis over the mesh's
worker axes).  Byzantine *data* corruption happens here, on the shards
of the byzantine workers, exactly as in the paper where byzantine
machines "compute gradients on these data": any data-scope
``AttackSpec`` registered in :mod:`..core.threat` (label_flip ships)
applies its ``corrupt_labels`` rule to the workers selected by the
config's membership policy (``threat.data_membership``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..configs.base import ByzantineConfig, InputShape, ModelConfig
from ..core import threat
from .synthetic import TokenStream, fmnist_like


def data_attack_spec(byz: Optional[ByzantineConfig]):
    """The active data-scope AttackSpec, or None (gradient-scope and
    attack-free configs corrupt nothing here)."""
    if byz is None or byz.attack == "none" or byz.alpha <= 0:
        return None
    spec = threat.get_spec(byz.attack)
    return spec if spec.scope == "data" else None


def timing_attack_spec(byz: Optional[ByzantineConfig]):
    """The active timing-scope AttackSpec, or None.  Timing attacks
    (e.g. ``stall``) corrupt worker ARRIVAL, not data or gradients —
    they act on the :class:`ArrivalSchedule`'s delay vector."""
    if byz is None or byz.attack == "none" or byz.alpha <= 0:
        return None
    spec = threat.get_spec(byz.attack)
    return spec if spec.scope == "timing" else None


STRAGGLE_DISTS = ("none", "exp", "pareto")


def parse_straggle(arg: str) -> tuple:
    """Parse a ``dist[:scale]`` straggle argument into ``(dist, scale)``.

    One parser for every entry point (CLI, chaos harness, tests) so the
    error text always names the legal distributions.  ``none`` takes no
    scale; ``exp``/``pareto`` default to scale 1.0 and reject
    non-positive scales loudly."""
    dist, sep, scale_s = str(arg).partition(":")
    if dist not in STRAGGLE_DISTS:
        raise ValueError(
            f"straggle distribution {dist!r}: choose from "
            f"{', '.join(STRAGGLE_DISTS)} (format: dist[:scale], "
            f"e.g. exp:0.5)")
    if not sep:
        return dist, 1.0
    if dist == "none":
        raise ValueError("straggle 'none' takes no scale")
    try:
        scale = float(scale_s)
    except ValueError:
        raise ValueError(
            f"straggle scale {scale_s!r} is not a number "
            f"(format: dist[:scale], e.g. pareto:2.0)") from None
    if not scale > 0:
        raise ValueError(f"straggle scale must be positive, got {scale}")
    return dist, scale


class ArrivalSchedule:
    """Per-step worker arrival delays and the quorum-selected active
    set (DESIGN.md §Elastic).

    Drops the synchronous-round fiction host-side: each step draws an
    arrival delay per worker from ``straggle`` (``none`` | ``exp`` |
    ``pareto``, scaled by ``scale``), lets any timing-scope attack
    rewrite the delays of the byzantine workers (``stall`` pins them to
    +inf — they never arrive), and selects the first ``quorum`` workers
    to arrive as this round's active set.  Draws are keyed on
    ``(seed, step)`` so the schedule is reproducible and independent of
    the data stream.  ``active(step)`` is the [m] 0/1 f32 mask the
    elastic train step consumes; workers with non-finite delay are
    never active even when fewer than ``quorum`` arrive (the round then
    truthfully runs under-quorum rather than waiting forever)."""

    def __init__(self, n_workers: int, quorum: int, straggle: str = "none",
                 scale: float = 1.0, byz: Optional[ByzantineConfig] = None,
                 seed: int = 0):
        if straggle not in STRAGGLE_DISTS:
            raise ValueError(f"straggle={straggle!r}: "
                             f"choose from {', '.join(STRAGGLE_DISTS)}")
        if not 0 < quorum <= n_workers:
            raise ValueError(f"quorum={quorum} out of range for "
                             f"{n_workers} workers")
        self.m, self.quorum = n_workers, quorum
        self.straggle, self.scale = straggle, scale
        self.byz, self.seed = byz, seed

    def delays(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        if self.straggle == "exp":
            d = rng.exponential(self.scale, self.m)
        elif self.straggle == "pareto":
            d = rng.pareto(2.0, self.m) * self.scale
        else:
            d = np.zeros(self.m)
        spec = timing_attack_spec(self.byz)
        if spec is not None:
            is_byz = threat.data_membership(self.byz, self.m, step)
            d = spec.delay(d, is_byz, self.byz)
        return d

    def active(self, step: int) -> np.ndarray:
        d = self.delays(step)
        order = np.argsort(d, kind="stable")
        act = np.zeros(self.m, np.float32)
        act[order[:self.quorum]] = 1.0
        return act * np.isfinite(d)


class LMWorkerPipeline:
    """Token batches [m, b, S] for LM training."""

    def __init__(self, cfg: ModelConfig, n_workers: int, batch_per_worker: int,
                 seq_len: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None):
        self.cfg = cfg
        self.m = n_workers
        self.b = batch_per_worker
        self.seq = seq_len
        self.stream = TokenStream(cfg.vocab, seed=seed)
        self.byz = byz

    def batch(self, step: int) -> dict:
        toks = self.stream.batch(step, self.m * self.b, self.seq)
        toks = toks.reshape(self.m, self.b, self.seq)
        spec = data_attack_spec(self.byz)
        if spec is not None:
            # corrupt the byzantine workers' target stream
            mask = threat.data_membership(self.byz, self.m, step)
            toks[mask] = spec.corrupt_labels(toks[mask], self.cfg.vocab)
        out = {"tokens": toks}
        if self.cfg.n_prefix_tokens:
            rng = np.random.default_rng(step)
            out["prefix_embed"] = rng.normal(
                0, 0.02, size=(self.m, self.b, self.cfg.n_prefix_tokens,
                               self.cfg.d_model)).astype(np.float32)
        return out


class ImageWorkerPipeline:
    """FashionMNIST-like shards for the LeNet repro: each worker owns n
    samples (paper: i.i.d. per-worker datasets); byzantine workers'
    labels are corrupted by any registered data-scope attack.  The
    dataset stays CLEAN in storage and corruption is applied per
    ``batch(step)`` from a step-keyed membership mask — exactly like
    the LM pipeline — so the ``resample`` policy draws a fresh
    byzantine set every step instead of degenerating to the step-0
    draw (the previous behaviour: the dataset was corrupted once at
    construction)."""

    def __init__(self, n_workers: int, n_per_worker: int, seed: int = 0,
                 byz: Optional[ByzantineConfig] = None, n_classes: int = 10):
        self.m, self.n = n_workers, n_per_worker
        self.byz, self.n_classes = byz, n_classes
        imgs, labels = fmnist_like(n_workers * n_per_worker, seed=seed)
        self.images = imgs.reshape(n_workers, n_per_worker, *imgs.shape[1:])
        self.labels = labels.reshape(n_workers, n_per_worker)
        self.test_images, self.test_labels = fmnist_like(2048, seed=seed + 777)

    def batch(self, step: int, batch_per_worker: int) -> dict:
        rng = np.random.default_rng(step)
        idx = rng.integers(0, self.n, size=(self.m, batch_per_worker))
        labels = np.stack([self.labels[w, idx[w]] for w in range(self.m)])
        spec = data_attack_spec(self.byz)
        if spec is not None:
            mask = threat.data_membership(self.byz, self.m, step)
            labels[mask] = spec.corrupt_labels(labels[mask], self.n_classes)
        return {
            "images": np.stack([self.images[w, idx[w]] for w in range(self.m)]),
            "labels": labels,
        }
