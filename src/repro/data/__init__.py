from .pipeline import ImageWorkerPipeline, LMWorkerPipeline
from .synthetic import TokenStream, flip_labels, fmnist_like
