from .pipeline import ImageWorkerPipeline, LMWorkerPipeline
from .synthetic import TokenStream, fmnist_like
