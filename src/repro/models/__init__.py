from .params import (ParamDef, abstract_params, count_params, init_params,
                     pspec_tree, shard_hint, shardings_tree, tree_map_defs)
from .transformer import (cache_defs, decode_step, forward, init_cache,
                          loss_fn, param_defs, segments)
from .lenet import lenet_defs, lenet_forward, lenet_loss, lenet_accuracy
