"""Mamba2 (SSD) block — chunked state-space dual form.

Follows "Transformers are SSMs" (Dao & Gu, 2024): within-chunk quadratic
form + inter-chunk recurrence carried with ``lax.scan``.  Single group
(n_groups=1) B/C, per-head scalar decay A, depthwise causal conv over
the (x,B,C) projection, gated RMSNorm output.

Decode keeps O(1) state: conv ring (width-1 last inputs) + SSM state
[B,H,N,P] — this is what makes zamba2 run ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import SSMSpec
from .layers import rms_norm
from .params import ParamDef


def dims(d_model: int, s: SSMSpec):
    d_inner = s.expand * d_model
    n_heads = s.n_heads or d_inner // s.head_dim
    return d_inner, n_heads


def mamba2_defs(d_model: int, s: SSMSpec) -> dict:
    di, H = dims(d_model, s)
    N, W = s.state_dim, s.conv_width
    return {
        "w_z": ParamDef((d_model, di), ("embed", "inner")),
        "w_x": ParamDef((d_model, di), ("embed", "inner")),
        "w_B": ParamDef((d_model, N), ("embed", "state")),
        "w_C": ParamDef((d_model, N), ("embed", "state")),
        "w_dt": ParamDef((d_model, H), ("embed", "heads")),
        "conv_k": ParamDef((W, di + 2 * N), ("conv", None), init="normal", scale=0.5),
        "conv_b": ParamDef((di + 2 * N,), (None,), init="zeros"),
        "dt_bias": ParamDef((H,), (None,), init="zeros"),
        "A_log": ParamDef((H,), (None,), init="zeros"),
        "D_skip": ParamDef((H,), (None,), init="ones"),
        "gamma": ParamDef((di,), (None,), init="ones"),
        "w_out": ParamDef((di, d_model), ("inner", "embed")),
    }


def _causal_conv(xbc, kern, bias, state=None):
    """Depthwise causal conv.  xbc: [B,S,C]; kern: [W,C].

    state: [B,W-1,C] previous inputs (decode) or None (pad with zeros).
    Returns (out [B,S,C], new_state [B,W-1,C])."""
    W = kern.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    ext = jnp.concatenate([state, xbc], axis=1)              # [B,S+W-1,C]
    out = sum(ext[:, i:i + xbc.shape[1]] * kern[i] for i in range(W))
    new_state = ext[:, -(W - 1):]
    return out + bias, new_state


def _ssd_chunked(xh, dt, A, Bc, Cc, chunk: int):
    """Chunked SSD scan.

    xh [B,S,H,P], dt [B,S,H], A [H] (negative), Bc/Cc [B,S,N].
    Returns y [B,S,H,P], final_state [B,H,N,P].
    """
    Bsz, S, H, Pd = xh.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    T = xh.shape[1]
    C = T // Q
    f32 = jnp.float32
    xh = xh.reshape(Bsz, C, Q, H, Pd).astype(f32)
    dt = dt.reshape(Bsz, C, Q, H).astype(f32)
    Bc = Bc.reshape(Bsz, C, Q, N).astype(f32)
    Cc = Cc.reshape(Bsz, C, Q, N).astype(f32)
    dA = dt * A.astype(f32)                                  # [B,C,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                             # within-chunk
    dtx = xh * dt[..., None]                                 # dt-weighted input
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,C,Q,Q,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,C,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", G, L, dtx)
    # chunk-local end states: S_c = sum_j exp(cum_last - cum_j) B_j dtx_j^T
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,C,Q,H]
    S_loc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, dec_to_end, dtx)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # [B,C,H]

    def step(S_prev, inp):
        S_l, dec = inp                                       # [B,H,N,P], [B,H]
        S_new = S_prev * dec[:, :, None, None] + S_l
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, H, N, Pd), f32)
    S_final, S_prevs = jax.lax.scan(
        step, S0, (S_loc.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    S_prevs = S_prevs.swapaxes(0, 1)                         # [B,C,H,N,P]
    # inter-chunk contribution: y_i += exp(cum_i) C_i . S_prev
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, T, H, Pd)[:, :S]
    return y, S_final


def mamba2_forward(p, s: SSMSpec, x, conv_state=None, ssm_state=None):
    """Full-sequence forward.  x: [B,S,D].  Returns (out, (conv_st, ssm_st))."""
    di, H = dims(x.shape[-1], s)
    N = s.state_dim
    Pd = di // H
    z = x @ p["w_z"]
    xbc = jnp.concatenate([x @ p["w_x"], x @ p["w_B"], x @ p["w_C"]], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_k"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xc, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(*xc.shape[:2], H, Pd)
    y, ssm_state = _ssd_chunked(xh, dt, A, Bc, Cc, s.chunk)
    y = y + p["D_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*xc.shape[:2], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gamma"])
    return y @ p["w_out"], (conv_state, ssm_state)


def mamba2_decode(p, s: SSMSpec, x, conv_state, ssm_state):
    """Single-token decode.  x: [B,1,D]; O(1) state update."""
    di, H = dims(x.shape[-1], s)
    N = s.state_dim
    Pd = di // H
    z = x @ p["w_z"]
    xbc = jnp.concatenate([x @ p["w_x"], x @ p["w_B"], x @ p["w_C"]], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_k"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xc, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # [B,1,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(-1, H, Pd).astype(jnp.float32)           # [B,H,P]
    dt1 = dt[:, 0]                                           # [B,H]
    dA = jnp.exp(dt1 * A)                                    # [B,H]
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bc[:, 0].astype(jnp.float32), dt1, xh)
    ssm_state = ssm_state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), ssm_state)
    y = y + p["D_skip"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gamma"])
    return y @ p["w_out"], (conv_state, ssm_state)
