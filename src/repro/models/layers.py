"""Shared transformer layers: RMSNorm, RoPE, activations, GQA / MLA /
sliding-window attention (train + prefill + single-token decode), MLPs.

All functions are pure; parameters come in as pytrees declared by the
``*_defs`` functions in terms of :class:`repro.models.params.ParamDef`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AttentionSpec, ModelConfig
from .params import ParamDef

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def activate(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":          # squared ReLU (nemotron / rwkv channel-mix)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D] (D even), positions: [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv     # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, gated: bool) -> dict:
    d = {
        "w_in": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_out": ParamDef((d_ff, d_model), ("ff", "embed")),
    }
    if gated:
        d["w_gate"] = ParamDef((d_model, d_ff), ("embed", "ff"))
    return d


def mlp(p, x, activation: str):
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = activate(x @ p["w_gate"], activation) * h
    else:
        h = activate(h, activation)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / sliding window) — batched full-seq form
# ---------------------------------------------------------------------------

def gqa_defs(d_model: int, a: AttentionSpec) -> dict:
    d = {
        "wq": ParamDef((d_model, a.n_heads, a.head_dim), ("embed", "heads", "hd")),
        "wk": ParamDef((d_model, a.n_kv_heads, a.head_dim), ("embed", "kv", "hd")),
        "wv": ParamDef((d_model, a.n_kv_heads, a.head_dim), ("embed", "kv", "hd")),
        "wo": ParamDef((a.n_heads, a.head_dim, d_model), ("heads", "hd", "embed")),
    }
    if a.qk_norm:
        d["q_norm"] = ParamDef((a.head_dim,), (None,), init="ones")
        d["k_norm"] = ParamDef((a.head_dim,), (None,), init="ones")
    return d


def _causal_window_mask(sq: int, skv: int, window: int, q_offset: int = 0):
    """[sq, skv] boolean mask.  q position i attends to kv position j iff
    j <= i and (window == 0 or i - j < window)."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def _sdpa(q, k, v, mask):
    """q [B,S,H,D], k/v [B,T,Hkv,D] with H multiple of Hkv.

    §Perf traffic layout: the S×T score tensor is touched in as few
    passes as possible — max WITHOUT the mask (masked entries are real
    qk products of the same scale, so exp(l - m_all) stays in [0,1]),
    one fused mask+exp producing bf16 weights, and the 1/Σ normalizer
    folded into the small [B,S,H,D] output instead of a full S×T divide.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    q = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bshgd,bthd->bhgst", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (1.0 / np.sqrt(D))
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, axis=-1)                                  # [B,Hkv,G,S] f32
    out = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, S, H, D).astype(v.dtype)


def gqa_attention(p, a: AttentionSpec, x, positions, mask=None):
    """Full-sequence attention.  x: [B,S,d]; positions: [S] or [B,S]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions.ndim == 1:
        positions = positions[None, :]
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    if mask is None:
        mask = _causal_window_mask(x.shape[1], x.shape[1], a.window)
    out = _sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def _decode_pos(pos, B: int):
    """Broadcast a scalar or per-slot ``[B]`` position vector to [B].

    Continuous batching gives every batch slot its own absolute position
    (requests join mid-stream); single-request decode passes a scalar.
    """
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))


def gqa_decode(p, a: AttentionSpec, x, cache_k, cache_v, pos):
    """Single-token decode.  x: [B,1,d]; cache_k/v: [B,T,Hkv,D] rolling or
    absolute buffer; ``pos``: scalar absolute position of the new token,
    or a per-slot ``[B]`` vector (continuous batching — every slot decodes
    at its own position).

    With a sliding window the cache length T == window and entries are a
    ring buffer indexed pos % window; otherwise T is the max seq len.
    """
    B, _, _ = x.shape
    T = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    posb = _decode_pos(pos, B)
    posv = posb[:, None]                             # [B,1]
    q = apply_rope(q, posv, a.rope_theta)
    k = apply_rope(k, posv, a.rope_theta)
    slot = posb % T if a.window else posb            # [B]
    cache_k = cache_k.at[jnp.arange(B), slot].set(k[:, 0])
    cache_v = cache_v.at[jnp.arange(B), slot].set(v[:, 0])
    # validity: slots holding tokens <= pos and within window, per batch
    idx = jnp.arange(T)
    if a.window:
        # slot j holds absolute position: the most recent write <= pos
        age = (slot[:, None] - idx[None, :]) % T
        valid = age < jnp.minimum(posb + 1, T)[:, None]
    else:
        valid = idx[None, :] <= posb[:, None]
    mask = valid[:, None, None, None, :]             # [B,1,1,1,T] -> bhgst
    out = _sdpa(q, cache_k, cache_v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3): low-rank latent KV, decoupled RoPE key
# ---------------------------------------------------------------------------

def mla_defs(d_model: int, a: AttentionSpec) -> dict:
    qk_head = a.qk_nope_dim + a.qk_rope_dim
    d: dict = {}
    if a.q_lora_rank:
        d["w_dq"] = ParamDef((d_model, a.q_lora_rank), ("embed", "qlora"))
        d["q_norm"] = ParamDef((a.q_lora_rank,), (None,), init="ones")
        d["w_uq"] = ParamDef((a.q_lora_rank, a.n_heads, qk_head), ("qlora", "heads", "hd"))
    else:
        d["w_uq"] = ParamDef((d_model, a.n_heads, qk_head), ("embed", "heads", "hd"))
    d["w_dkv"] = ParamDef((d_model, a.kv_lora_rank), ("embed", "kvlora"))
    d["kv_norm"] = ParamDef((a.kv_lora_rank,), (None,), init="ones")
    d["w_krope"] = ParamDef((d_model, a.qk_rope_dim), ("embed", None))
    d["w_uk"] = ParamDef((a.kv_lora_rank, a.n_heads, a.qk_nope_dim), ("kvlora", "heads", "hd"))
    d["w_uv"] = ParamDef((a.kv_lora_rank, a.n_heads, a.v_head_dim), ("kvlora", "heads", "hd"))
    d["wo"] = ParamDef((a.n_heads, a.v_head_dim, d_model), ("heads", "hd", "embed"))
    return d


def _mla_q(p, a: AttentionSpec, x, positions):
    if a.q_lora_rank:
        cq = rms_norm(x @ p["w_dq"], p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])
    q_nope, q_rope = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, a.rope_theta)
    return q_nope, q_rope


def mla_attention(p, a: AttentionSpec, x, positions, mask=None):
    """Full-sequence MLA.  Returns output and the latent cache pieces."""
    B, S, _ = x.shape
    if positions.ndim == 1:
        positions = positions[None, :]
    q_nope, q_rope = _mla_q(p, a, x, positions)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"])           # [B,S,R]
    k_rope = apply_rope((x @ p["w_krope"])[:, :, None, :], positions,
                        a.rope_theta)                        # [B,S,1,Dr]
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    scale = 1.0 / jnp.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    k_rope_sq = k_rope.squeeze(2)                            # [B,S,Dr]
    logits = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope) +
              jnp.einsum("bshk,btk->bhst", q_rope, k_rope_sq)
              ).astype(jnp.float32) * scale
    if mask is None:
        mask = _causal_window_mask(S, S, a.window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthk->bshk", w, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (c_kv, k_rope.squeeze(2))


def mla_decode(p, a: AttentionSpec, x, cache_c, cache_kr, pos):
    """Weight-absorbed single-token MLA decode.

    cache_c: [B,T,R] latent; cache_kr: [B,T,Dr] rope key; ``pos``:
    scalar or per-slot ``[B]`` absolute positions (see gqa_decode).
    score_h(t) = q_nope_h · (c_t W_uk,h) + q_rope_h · k_rope_t
               = (W_uk,h^T q_nope_h) · c_t + q_rope_h · k_rope_t
    out_h = Σ_t w_t (c_t W_uv,h)  = (Σ_t w_t c_t) W_uv,h   (absorbed)
    """
    B = x.shape[0]
    posb = _decode_pos(pos, B)
    posv = posb[:, None]                                     # [B,1]
    q_nope, q_rope = _mla_q(p, a, x, posv)                   # [B,1,H,*]
    c_new = rms_norm(x @ p["w_dkv"], p["kv_norm"])           # [B,1,R]
    kr_new = apply_rope((x @ p["w_krope"])[:, :, None, :], posv,
                        a.rope_theta).squeeze(2)             # [B,1,Dr]
    cache_c = cache_c.at[jnp.arange(B), posb].set(c_new[:, 0])
    cache_kr = cache_kr.at[jnp.arange(B), posb].set(kr_new[:, 0])
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])  # [B,1,H,R]
    scale = 1.0 / jnp.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    logits = (jnp.einsum("bshr,btr->bhst", q_abs, cache_c) +
              jnp.einsum("bshk,btk->bhst", q_rope, cache_kr)).astype(jnp.float32)
    logits = logits * scale
    valid = (jnp.arange(cache_c.shape[1])[None, :] <= posb[:, None])
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cache_c.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, cache_c)           # [B,1,H,R]
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (cache_c, cache_kr)
