"""RWKV6 "Finch" block — attention-free time-mix with data-dependent
decay (WKV6) + squared-ReLU channel-mix.

Time-mix state per head: S ∈ R^{K×K}; per token
    y_t   = r_t · (S_t + diag(u)·k_t v_tᵀ)
    S_t+1 = diag(w_t)·S_t + k_t v_tᵀ
with w_t = exp(-exp(base + lora(x'_t))) data-dependent per channel.

Prefill/train runs the recurrence with ``lax.scan`` over time (baseline;
a chunked parallel form is a §Perf candidate).  Decode carries
(S, last_x_tm, last_x_cm) — O(1) state, enabling ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..configs.base import RWKVSpec
from .layers import activate, rms_norm
from .params import ParamDef, shard_hint

# WKV runs head-parallel: the recurrence couples all of S and K within a
# head but heads are independent — shard H over 'model' (sequence stays
# whole).  §Perf: without this hint XLA keeps the [B,S,H,K] intermediates
# replicated over 'model' (16x the traffic).
_HEAD_SPEC = P(None, None, "model", None)
# layer IO stays sequence-sharded over 'model'; the time-mix gathers the
# bf16 activations ONCE per layer (cheap) and produces r/k/v/w locally
# head-sharded from column-sharded weights (no f32 reshards).
_SEQ_SPEC = P(None, "model", None)

_MIX = 5  # r,k,v,w,g


def rwkv6_defs(d_model: int, d_ff: int, r: RWKVSpec) -> dict:
    H = d_model // r.head_dim
    K = r.head_dim
    return {
        # time-mix
        "mu": ParamDef((_MIX, d_model), (None, "embed"), init="zeros"),
        "mix_A": ParamDef((d_model, _MIX * r.mix_lora), ("embed", None), scale=0.1),
        "mix_B": ParamDef((_MIX, r.mix_lora, d_model), (None, None, "embed"), scale=0.1),
        "w_r": ParamDef((d_model, d_model), ("embed", "heads")),
        "w_k": ParamDef((d_model, d_model), ("embed", "heads")),
        "w_v": ParamDef((d_model, d_model), ("embed", "heads")),
        "w_g": ParamDef((d_model, d_model), ("embed", "heads")),
        "decay_base": ParamDef((d_model,), (None,), init="zeros"),
        "decay_A": ParamDef((d_model, r.decay_lora), ("embed", None), scale=0.1),
        "decay_B": ParamDef((r.decay_lora, d_model), (None, "embed"), scale=0.1),
        "bonus_u": ParamDef((H, K), (None, None), init="zeros"),
        "ln_gamma": ParamDef((d_model,), (None,), init="ones"),
        "w_o": ParamDef((d_model, d_model), ("heads", "embed")),
        # channel-mix
        "cm_mu": ParamDef((2, d_model), (None, "embed"), init="zeros"),
        "w_ck": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_cv": ParamDef((d_ff, d_model), ("ff", "embed")),
        "w_cr": ParamDef((d_model, d_model), ("embed", "embed")),
    }


def _shift(x, last=None):
    """x_{t-1} along seq.  last: [B,1,D] carry for decode/chunk stitch."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(x, xprev, mu, mix_A, mix_B):
    """Data-dependent lerp producing the 5 mixed inputs [5,B,S,D]."""
    diff = xprev - x
    xx = x + diff * 0.5                                      # coarse mix for the lora input
    lora = jnp.tanh(xx @ mix_A)                              # [B,S,5*rank]
    lora = lora.reshape(*lora.shape[:2], _MIX, -1)           # [B,S,5,rank]
    dyn = jnp.einsum("bsmr,mrd->mbsd", lora, mix_B)          # [5,B,S,D]
    mix = mu[:, None, None, :] + dyn                         # [5,B,S,D]
    return x[None] + diff[None] * mix


def _wkv_scan(r, k, v, w, u, S0):
    """r,k,v: [B,S,H,K]; w: [B,S,H,K] decay in (0,1); u: [H,K].
    Returns y [B,S,H,K], final state [B,H,K,K]."""

    def step(S, inp):
        rt, kt, vt, wt = inp                                 # [B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]             # [B,H,K,K]
        y = jnp.einsum("bhk,bhkj->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))       # [S,B,H,K]
    S_final, ys = jax.lax.scan(step, S0, xs)
    return ys.swapaxes(0, 1), S_final


_LOG_CLAMP = 40.0    # factor magnitudes <= e^40; pair products <= e^80 < f32 max


def _wkv_chunked(r, k, v, w, u, S0, chunk: int):
    """Chunked-parallel WKV6 (beyond-paper §Perf: flash-linear-attention
    style).  Within a chunk of Q tokens the recurrence unrolls to an
    attention-like quadratic form

        y_t = (r_t ⊙ e^{ce_t}) · S_in
            + Σ_{j<t} [(r_t ⊙ e^{ce_t}) · (k_j ⊙ e^{-c_j})] v_j
            + (r_t ⊙ u) · k_t  v_t

    with c = within-chunk inclusive cumsum(log w), ce = exclusive, so the
    carried state advances once per CHUNK (Q× fewer scan steps / saved
    states than the per-token scan).

    Numerics: the factorized form needs exp(±c) representable.  c is
    CENTERED per (batch, head, channel, chunk) — the shift cancels in
    ce_t - c_j — giving an exact window of 2·_LOG_CLAMP = 80 nats of
    within-chunk decay range; beyond that, factors clamp (affected terms
    carry true weight < e^-40).  Q=32 is exact for per-step decay
    w >= e^-2.5; pathological faster decays fall back to chunk=0 (scan).

    Matches ``_wkv_scan`` (tests/test_moe_ssm.py sweeps parity).
    """
    B, S, H, K = r.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zp = lambda t, val=0.0: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                                        constant_values=val)
        r, k, v = zp(r), zp(k), zp(v)
        w = zp(w, 1.0)                               # decay 1 = no-op
    C = r.shape[1] // Q
    resh = lambda t: t.reshape(B, C, Q, H, K).swapaxes(0, 1)  # [C,B,Q,H,K]
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)

    @jax.checkpoint      # bwd recomputes intra-chunk factors from inputs:
    def body(S_in, inp):  # only the [B,H,K,K] carry is saved per chunk
        rq, kq, vq, wq = inp                          # [B,Q,H,K]
        c = jnp.cumsum(jnp.log(wq), axis=1)           # inclusive [B,Q,H,K]
        ce = c - jnp.log(wq)                          # exclusive
        # intra-chunk factors are centered per (b,h,k): the shift cancels
        # in ce_t - c_j and doubles the representable decay range
        mid = 0.5 * c[:, -1:]
        r_dec = rq * jnp.exp(jnp.clip(ce - mid, -_LOG_CLAMP, _LOG_CLAMP))
        k_grow = kq * jnp.exp(jnp.clip(mid - c, -_LOG_CLAMP, _LOG_CLAMP))
        # the incoming-state term needs the UNSHIFTED decay (ce <= 0)
        r_state = rq * jnp.exp(jnp.maximum(ce, -2 * _LOG_CLAMP))
        # intra-chunk scores A[t,j] for j < t (strictly causal)
        A = jnp.einsum("bthk,bjhk->bhtj", r_dec, k_grow)
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        y = jnp.einsum("bhtj,bjhk->bthk", A, vq)
        # current-token bonus term
        diag = jnp.einsum("bthk,bthk->bth", rq * u[None, None], kq)
        y = y + diag[..., None] * vq
        # inter-chunk: incoming state
        y = y + jnp.einsum("bthk,bhkj->bthj", r_state, S_in)
        # state update to chunk end
        k_end = kq * jnp.exp(jnp.maximum(c[:, -1:] - c, -2 * _LOG_CLAMP))
        S_out = (jnp.exp(jnp.maximum(c[:, -1], -2 * _LOG_CLAMP))[..., None] * S_in
                 + jnp.einsum("bjhk,bjhn->bhkn", k_end, vq))
        return S_out, y

    S_final, ys = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, C * Q, H, K)[:, :S]
    return y, S_final


def rwkv6_timemix(p, r: RWKVSpec, x, last_x=None, state=None):
    B, S, D = x.shape
    H, K = D // r.head_dim, r.head_dim
    xprev = _shift(x, last_x)
    mixed = _ddlerp(x.astype(jnp.float32), xprev.astype(jnp.float32),
                    p["mu"].astype(jnp.float32), p["mix_A"], p["mix_B"])
    xr, xk, xv, xw, xg = [m.astype(x.dtype) for m in mixed]
    rr = (xr @ p["w_r"]).reshape(B, S, H, K).astype(jnp.float32)
    kk = (xk @ p["w_k"]).reshape(B, S, H, K).astype(jnp.float32)
    vv = (xv @ p["w_v"]).reshape(B, S, H, K).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    dec = p["decay_base"].astype(jnp.float32) + jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, H, K)
    if state is None:
        state = jnp.zeros((B, H, K, K), jnp.float32)
    if r.chunk and S > 1:
        y, state = _wkv_chunked(rr, kk, vv, w,
                                p["bonus_u"].astype(jnp.float32), state,
                                r.chunk)
    else:
        y, state = _wkv_scan(rr, kk, vv, w, p["bonus_u"].astype(jnp.float32),
                             state)
    y = y.reshape(B, S, D)
    y = rms_norm(y, p["ln_gamma"]).astype(x.dtype) * g
    return shard_hint(y @ p["w_o"], _SEQ_SPEC), (x[:, -1:], state)


def rwkv6_channelmix(p, x, last_x=None):
    xprev = _shift(x, last_x)
    diff = xprev - x
    xk = x + diff * p["cm_mu"][0]
    xr = x + diff * p["cm_mu"][1]
    k = activate(xk @ p["w_ck"], "relu2")
    out = jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])
    return shard_hint(out, _SEQ_SPEC), x[:, -1:]
