"""Mixture-of-Experts FFN with capacity-based expert-parallel dispatch.

TPU mapping: expert weights are sharded over the ``model`` mesh axis;
activations are replicated across ``model`` (tensor-parallel layout), so
"dispatch" is a local gather of each shard's experts' tokens — the only
collective is the output reduction, which XLA emits as an all-reduce
over ``model``.  This adapts the paper-agnostic GShard capacity design
to the mesh used by this framework (see DESIGN.md §2/§6).

Routing is token-choice top-k with per-expert capacity
``C_e = ceil(T * k / E * capacity_factor)``; over-capacity assignments
are dropped (standard GShard semantics).  Setting
``capacity_factor >= E / k`` makes dispatch lossless — tests use that to
compare against the dense oracle in ``ref_dense_moe``.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, MoESpec
from .layers import activate
from .params import ParamDef, shard_hint


def moe_defs(d_model: int, m: MoESpec) -> dict:
    e, f = m.n_experts, m.d_ff_expert
    d = {
        "router": ParamDef((d_model, e), ("embed", None)),
        "w_in": ParamDef((e, d_model, f), ("experts", "embed", "ff")),
        "w_gate": ParamDef((e, d_model, f), ("experts", "embed", "ff")),
        "w_out": ParamDef((e, f, d_model), ("experts", "ff", "embed")),
    }
    if m.n_shared:
        d["shared_in"] = ParamDef((d_model, m.n_shared * f), ("embed", "ff"))
        d["shared_gate"] = ParamDef((d_model, m.n_shared * f), ("embed", "ff"))
        d["shared_out"] = ParamDef((m.n_shared * f, d_model), ("ff", "embed"))
    return d


def capacity(n_tokens: int, m: MoESpec) -> int:
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, int(c))


def route(router_w, x, m: MoESpec):
    """Returns (weights [T,k], expert ids [T,k], aux losses)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # [T,E]
    w, ids = jax.lax.top_k(probs, m.top_k)                   # [T,k]
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # aux: load-balance (Switch) + router z-loss
    T = x.shape[0]
    me = jnp.mean(probs, axis=0)                             # mean prob per expert
    ce = jnp.zeros((m.n_experts,)).at[ids.reshape(-1)].add(1.0) / (T * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2) * m.router_zloss
    return w, ids, aux + z


def dispatch_indices(ids, w, m: MoESpec, cap: int):
    """GShard-style position-in-expert computation.

    ids/w: [T, k].  Returns token index matrix [E, C], combine weights
    [E, C], validity [E, C], and the inverse map slot_of [T, k] into the
    flattened [E*C] slot space (dropped assignments point at slot E*C —
    a zero pad row on the combine side).
    """
    T, k = ids.shape
    E = m.n_experts
    flat_ids = ids.reshape(-1)                               # [T*k]
    flat_w = w.reshape(-1)
    # position of each assignment within its expert (arrival order)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)    # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot           # 1-based
    pos = jnp.sum(pos_in_e, axis=-1) - 1                     # [T*k]
    keep = pos < cap
    # scatter into [E, C]
    tok_of = jnp.tile(jnp.arange(T)[:, None], (1, k)).reshape(-1)
    e_idx = jnp.where(keep, flat_ids, E)                     # drop -> row E
    p_idx = jnp.where(keep, pos, 0)
    tok_mat = jnp.zeros((E + 1, cap), jnp.int32).at[e_idx, p_idx].set(tok_of, mode="drop")
    w_mat = jnp.zeros((E + 1, cap), flat_w.dtype).at[e_idx, p_idx].set(flat_w, mode="drop")
    val = jnp.zeros((E + 1, cap), bool).at[e_idx, p_idx].set(keep, mode="drop")
    slot_of = jnp.where(keep, flat_ids * cap + pos, E * cap).reshape(T, k)
    return tok_mat[:E], w_mat[:E], val[:E], slot_of


def moe_ffn(p, x, m: MoESpec, activation: str = "silu",
            expert_spec: Tuple = ("model",)) -> Tuple[jax.Array, jax.Array]:
    """x: [T, d] (already flattened tokens).  Returns (out [T,d], aux)."""
    T, d = x.shape
    w, ids, aux = route(p["router"], x, m)
    cap = capacity(T, m)
    tok, cw, val, slot_of = dispatch_indices(ids, w.astype(x.dtype), m, cap)
    # shard dispatch tensors over experts so the gather/matmul are local
    espec = P(expert_spec[0] if len(expert_spec) == 1 else expert_spec)
    tok = shard_hint(tok, espec)
    # §Perf dispatch layout: token gathers with data-dependent indices
    # cannot cross shards without SPMD falling back to masked-gather +
    # all-reduce of the FULL result.  Reshard x to d-sharded (token dim
    # whole) so the gather is local, then a2a the packed [E,C,d] to the
    # expert layout.
    xd = shard_hint(x, P(None, espec[0]))
    xe = xd[tok]                                             # [E, C, d]
    xe = jnp.where(val[..., None], xe, 0)
    xe = shard_hint(xe, P(espec[0], None, None))
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h = activate(g, activation) * h
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])           # [E, C, d]
    ye = ye * jnp.where(val, cw, 0)[..., None].astype(ye.dtype)
    # combine: each token GATHERS its k slots from the (padded) expert
    # outputs.  §Perf: the natural scatter-add combine is unshardable
    # under SPMD (XLA all-gathers the 8GB token tensor per layer).  The
    # gather side is made LOCAL by resharding the [E*C+1, d] expert
    # outputs to d-sharded (slot dim whole) — one a2a — after which the
    # backward is a local scatter-add on that small tensor.
    ye_flat = jnp.concatenate(
        [ye.reshape(ye.shape[0] * cap, d),
         jnp.zeros((1, d), ye.dtype)], axis=0)               # slot E*C = 0
    ye_flat = shard_hint(ye_flat, P(None, espec[0]))
    out = jnp.sum(ye_flat[slot_of], axis=1)                  # [T,k,d/s]->[T,d/s]
    out = shard_hint(out, P(espec[0], None))                 # back to seq-shard
    if m.n_shared:
        hs = x @ p["shared_in"]
        hs = activate(x @ p["shared_gate"], activation) * hs
        out = out + hs @ p["shared_out"]
    return out, aux


def ref_dense_moe(p, x, m: MoESpec, activation: str = "silu"):
    """Oracle: computes every expert on every token, combines with router
    weights.  O(T·E·d·f) — tests only."""
    w, ids, _ = route(p["router"], x, m)
    h = jnp.einsum("td,edf->tef", x, p["w_in"])
    g = jnp.einsum("td,edf->tef", x, p["w_gate"])
    y = jnp.einsum("tef,efd->ted", activate(g, activation) * h, p["w_out"])
    combine = jnp.zeros((x.shape[0], m.n_experts), y.dtype)
    combine = combine.at[jnp.arange(x.shape[0])[:, None], ids].add(w.astype(y.dtype))
    out = jnp.einsum("te,ted->td", combine, y)
    if m.n_shared:
        hs = x @ p["shared_in"]
        hs = activate(x @ p["shared_gate"], activation) * hs
        out = out + hs @ p["shared_out"]
    return out
