"""Model assembly: config -> param defs -> forward / loss / decode.

Layers are grouped into homogeneous *segments* (identical block
structure) and executed with ``lax.scan`` over stacked parameters, so
the HLO stays compact for 512-device dry-run compiles:

  dense/vlm/audio : [("dense", L)]
  deepseek-v2     : [("dense", 1), ("moe", 59)]
  dbrx            : [("moe", 40)]
  rwkv6           : [("rwkv", 32)]
  zamba2          : [("hybrid", 9 units x (6 mamba + shared attn block))]
                    (shared attention params live outside the stack)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import layers as L
from . import mamba2 as M2
from . import moe as MOE
from . import rwkv6 as R6
from .params import ParamDef, shard_hint, tree_map_defs


class Segment(NamedTuple):
    kind: str      # dense | moe | rwkv | hybrid
    n: int         # scan length (layers, or units for hybrid)


def segments(cfg: ModelConfig):
    if cfg.arch_type == "ssm" and cfg.rwkv is not None:
        return [Segment("rwkv", cfg.n_layers)]
    if cfg.hybrid_attn_every:
        assert cfg.n_layers % cfg.hybrid_attn_every == 0
        return [Segment("hybrid", cfg.n_layers // cfg.hybrid_attn_every)]
    if cfg.is_moe:
        segs = []
        if cfg.n_dense_layers:
            segs.append(Segment("dense", cfg.n_dense_layers))
        segs.append(Segment("moe", cfg.n_layers - cfg.n_dense_layers))
        return segs
    return [Segment("dense", cfg.n_layers)]


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig):
    a = cfg.attention
    return L.mla_defs(cfg.d_model, a) if a.kind == "mla" else L.gqa_defs(cfg.d_model, a)


def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    D = cfg.d_model
    norm = lambda: ParamDef((D,), (None,), init="ones")
    if kind == "dense":
        return {"ln1": norm(), "attn": _attn_defs(cfg), "ln2": norm(),
                "mlp": L.mlp_defs(D, cfg.d_ff, gated=cfg.activation != "relu2")}
    if kind == "moe":
        return {"ln1": norm(), "attn": _attn_defs(cfg), "ln2": norm(),
                "moe": MOE.moe_defs(D, cfg.moe)}
    if kind == "rwkv":
        return {"ln1": norm(), "tm": R6.rwkv6_defs(D, cfg.d_ff, cfg.rwkv),
                "ln2": norm()}
    if kind == "mamba":
        return {"ln": norm(), "m": M2.mamba2_defs(D, cfg.ssm)}
    raise ValueError(kind)


def _stack(defs, n: int, axis_name="layers"):
    return tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale),
        defs)


def param_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    defs: dict = {
        "embed": ParamDef((cfg.vocab, D), ("vocab", "embed"), init="normal"),
        "final_norm": ParamDef((D,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, cfg.vocab), ("embed", "vocab"))
    for i, seg in enumerate(segments(cfg)):
        if seg.kind == "hybrid":
            unit = _stack(_block_defs(cfg, "mamba"), cfg.hybrid_attn_every, "sub")
            defs[f"seg_{i}"] = _stack(unit, seg.n, "units")
            defs["shared_attn"] = _block_defs(cfg, "dense")
        else:
            defs[f"seg_{i}"] = _stack(_block_defs(cfg, seg.kind), seg.n)
    return defs


# ---------------------------------------------------------------------------
# block bodies (full-sequence form)
# ---------------------------------------------------------------------------

def _attention(cfg, p, x, positions):
    if cfg.attention.kind == "mla":
        out, kv = L.mla_attention(p, cfg.attention, x, positions)
    else:
        out, kv = L.gqa_attention(p, cfg.attention, x, positions)
    return out, kv


def _kv_entry(cfg, kv):
    """Full-seq attention cache pieces, keyed like ``_attn_cache_defs``."""
    if cfg.attention.kind == "mla":
        return {"c": kv[0], "kr": kv[1]}
    return {"k": kv[0], "v": kv[1]}


def _dense_block(cfg, p, x, positions):
    h, kv = _attention(cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.rms_eps), positions)
    x = x + h
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"], cfg.rms_eps), cfg.activation)
    return x, jnp.zeros((), jnp.float32), _kv_entry(cfg, kv)


def _moe_block(cfg, p, x, positions):
    h, kv = _attention(cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.rms_eps), positions)
    x = x + h
    B, S, D = x.shape
    flat = L.rms_norm(x, p["ln2"], cfg.rms_eps).reshape(B * S, D)
    out, aux = MOE.moe_ffn(p["moe"], flat, cfg.moe, cfg.activation)
    return x + out.reshape(B, S, D), aux, _kv_entry(cfg, kv)


def _rwkv_block(cfg, p, x, positions):
    h, (tm_x, wkv) = R6.rwkv6_timemix(p["tm"], cfg.rwkv,
                                      L.rms_norm(x, p["ln1"], cfg.rms_eps))
    x = x + h
    h, cm_x = R6.rwkv6_channelmix(p["tm"], L.rms_norm(x, p["ln2"], cfg.rms_eps))
    return (x + h, jnp.zeros((), jnp.float32),
            {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x})


def _mamba_block(cfg, p, x):
    h, (conv, ssm) = M2.mamba2_forward(p["m"], cfg.ssm,
                                       L.rms_norm(x, p["ln"], cfg.rms_eps))
    return x + h, {"conv": conv, "ssm": ssm}


_SP_SPEC = P(None, "model", None)  # sequence-parallel activation layout


def _run_segment(cfg, seg: Segment, p_stack, shared, x, positions, remat=False,
                 param_hook=None, collect_cache=False):
    """Scan a stacked segment over x.  Returns (x, aux_sum, cache_ys).

    ``param_hook(p_layer, layer_idx)`` is applied to each scanned
    layer-slice of the parameter stack — identity by default.  The
    blocked aggregation mode injects its gather/robust-aggregate
    custom-VJP barrier here, so per-worker layer gradients are
    aggregated inside the backward scan and the full G matrix never
    materializes (DESIGN.md §2); ``layer_idx`` (f32 scalar) lets the
    barrier fold the layer position into its attack key so injected
    noise decorrelates across the scanned layers, not just across
    segments.

    ``collect_cache=True`` (fused prefill, DESIGN.md §Serve) stacks
    each layer's full-sequence cache pieces as scan ys — the stacked
    leading axis matches the ``cache_defs`` layout.  Training keeps
    ys=None so no cache memory rides along the backward pass.
    """

    def body(carry, idx_p):
        idx, p_l = idx_p
        x, aux = carry
        if param_hook is not None:
            p_l = param_hook(p_l, idx)
        x = shard_hint(x, _SP_SPEC)
        if seg.kind == "dense":
            x, a, ent = _dense_block(cfg, p_l, x, positions)
        elif seg.kind == "moe":
            x, a, ent = _moe_block(cfg, p_l, x, positions)
        elif seg.kind == "rwkv":
            x, a, ent = _rwkv_block(cfg, p_l, x, positions)
        elif seg.kind == "hybrid":
            def sub(xc, p_m):
                xc, st = _mamba_block(cfg, p_m, xc)
                return xc, (st if collect_cache else None)
            x, m_ent = jax.lax.scan(sub, x, p_l)
            x, a, a_ent = _dense_block(cfg, shared, x, positions)
            ent = {"mamba": m_ent, "attn": a_ent}
        else:
            raise ValueError(seg.kind)
        return (x, aux + a), (ent if collect_cache else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                (jnp.arange(seg.n, dtype=jnp.float32), p_stack))
    return x, aux, ys


# ---------------------------------------------------------------------------
# public forward / loss
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, tokens, prefix_embed=None):
    x = params["embed"][tokens]
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params, tokens, prefix_embed=None, remat=False,
            seg_hooks=None, top_hook=None):
    """tokens [B,S_tok] (+ optional prefix [B,P,D]) -> logits [B,S,V], aux.

    Blocked-aggregation hooks: ``seg_hooks["seg_i"]`` is applied to each
    scanned layer slice of segment i; ``top_hook`` once to the
    non-stacked bucket (embed / final_norm / lm_head / shared_attn).
    """
    if top_hook is not None:
        top = {k: v for k, v in params.items() if not k.startswith("seg_")}
        top = top_hook(top)
        params = {**params, **top}
    x = embed_inputs(cfg, params, tokens, prefix_embed)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    for i, seg in enumerate(segments(cfg)):
        hook = (seg_hooks or {}).get(f"seg_{i}")
        x, a, _ = _run_segment(cfg, seg, params[f"seg_{i}"],
                               params.get("shared_attn"), x, positions, remat,
                               hook)
        aux = aux + a
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = shard_hint(logits, P(None, None, "model"))
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch, remat=False, seg_hooks=None,
            top_hook=None):
    """Next-token cross-entropy over the token positions (prefix embeds
    from modality frontends are context only)."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens, batch.get("prefix_embed"), remat,
                          seg_hooks, top_hook)
    Pfx = logits.shape[1] - tokens.shape[1]
    # logits at position Pfx+t predict tokens[t+1]
    pred = logits[:, Pfx:-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        ce = -jnp.mean(ll)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode path (single new token against cache/state)
# ---------------------------------------------------------------------------

def _attn_cache_defs(cfg: ModelConfig, batch: int, seq_len: int):
    a = cfg.attention
    T = min(a.window, seq_len) if a.window else seq_len
    if a.kind == "mla":
        return {"c": ((batch, T, a.kv_lora_rank), ("batch", "seq", None)),
                "kr": ((batch, T, a.qk_rope_dim), ("batch", "seq", None))}
    return {"k": ((batch, T, a.n_kv_heads, a.head_dim), ("batch", "seq", "kv", "hd")),
            "v": ((batch, T, a.n_kv_heads, a.head_dim), ("batch", "seq", "kv", "hd"))}


def _mamba_cache_defs(cfg: ModelConfig, batch: int):
    di, H = M2.dims(cfg.d_model, cfg.ssm)
    N, W = cfg.ssm.state_dim, cfg.ssm.conv_width
    Pd = di // H
    return {"conv": ((batch, W - 1, di + 2 * N), ("batch", None, "inner")),
            "ssm": ((batch, H, N, Pd), ("batch", "heads", None, None))}


def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Shapes+logical axes of the decode cache, mirroring param stacking."""
    out: dict = {}
    for i, seg in enumerate(segments(cfg)):
        if seg.kind in ("dense", "moe"):
            out[f"seg_{i}"] = {
                k: ((seg.n,) + s, ("layers",) + ax)
                for k, (s, ax) in _attn_cache_defs(cfg, batch, seq_len).items()}
        elif seg.kind == "rwkv":
            D = cfg.d_model
            H, K = D // cfg.rwkv.head_dim, cfg.rwkv.head_dim
            out[f"seg_{i}"] = {
                "wkv": ((seg.n, batch, H, K, K), ("layers", "batch", "heads", None, None)),
                "tm_x": ((seg.n, batch, 1, D), ("layers", "batch", None, None)),
                "cm_x": ((seg.n, batch, 1, D), ("layers", "batch", None, None)),
            }
        elif seg.kind == "hybrid":
            sub = {k: ((seg.n, cfg.hybrid_attn_every) + s, ("units", "sub") + ax)
                   for k, (s, ax) in _mamba_cache_defs(cfg, batch).items()}
            attn = {k: ((seg.n,) + s, ("units",) + ax)
                    for k, (s, ax) in _attn_cache_defs(cfg, batch, seq_len).items()}
            out[f"seg_{i}"] = {"mamba": sub, "attn": attn}
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    defs = cache_defs(cfg, batch, seq_len)
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], dtype), defs,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def _attn_decode(cfg, p, x, cache, pos):
    a = cfg.attention
    if a.kind == "mla":
        out, (c, kr) = L.mla_decode(p, a, x, cache["c"], cache["kr"], pos)
        return out, {"c": c, "kr": kr}
    out, (k, v) = L.gqa_decode(p, a, x, cache["k"], cache["v"], pos)
    return out, {"k": k, "v": v}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token [B,1] int32; pos scalar int32 (absolute position) or a
    per-slot ``[B]`` vector — continuous batching decodes every slot at
    its own position (recurrent families ignore pos entirely).

    Returns (logits [B,1,V], new cache).  One new token, O(1) or O(T)
    work per layer depending on the block family.
    """
    x = params["embed"][token]

    new_cache: dict = {}
    for i, seg in enumerate(segments(cfg)):
        p_stack = params[f"seg_{i}"]
        c_stack = cache[f"seg_{i}"]
        if seg.kind in ("dense", "moe"):
            def body(x, pc):
                p_l, c_l = pc
                h, c_new = _attn_decode(cfg, p_l["attn"],
                                        L.rms_norm(x, p_l["ln1"], cfg.rms_eps), c_l, pos)
                x = x + h
                hin = L.rms_norm(x, p_l["ln2"], cfg.rms_eps)
                if seg.kind == "moe":
                    B = x.shape[0]
                    out, _ = MOE.moe_ffn(p_l["moe"], hin.reshape(B, -1), cfg.moe,
                                         cfg.activation)
                    x = x + out.reshape(B, 1, -1)
                else:
                    x = x + L.mlp(p_l["mlp"], hin, cfg.activation)
                return x, c_new
            x, c_new = jax.lax.scan(body, x, (p_stack, c_stack))
        elif seg.kind == "rwkv":
            def body(x, pc):
                p_l, c_l = pc
                h, (tm_x, wkv) = R6.rwkv6_timemix(
                    p_l["tm"], cfg.rwkv, L.rms_norm(x, p_l["ln1"], cfg.rms_eps),
                    last_x=c_l["tm_x"], state=c_l["wkv"].astype(jnp.float32))
                x = x + h
                h, cm_x = R6.rwkv6_channelmix(
                    p_l["tm"], L.rms_norm(x, p_l["ln2"], cfg.rms_eps),
                    last_x=c_l["cm_x"])
                x = x + h
                return x, {"wkv": wkv.astype(c_l["wkv"].dtype), "tm_x": tm_x,
                           "cm_x": cm_x}
            x, c_new = jax.lax.scan(body, x, (p_stack, c_stack))
        elif seg.kind == "hybrid":
            shared = params["shared_attn"]
            def body(x, pc):
                p_u, c_u = pc
                def sub(x, pm_cm):
                    p_m, c_m = pm_cm
                    h, (conv, ssm) = M2.mamba2_decode(
                        p_m["m"], cfg.ssm, L.rms_norm(x, p_m["ln"], cfg.rms_eps),
                        c_m["conv"], c_m["ssm"].astype(jnp.float32))
                    return x + h, {"conv": conv, "ssm": ssm.astype(c_m["ssm"].dtype)}
                x, m_new = jax.lax.scan(sub, x, (p_u["mamba"] if "mamba" in p_u else p_u,
                                                 c_u["mamba"]))
                h, a_new = _attn_decode(cfg, shared["attn"],
                                        L.rms_norm(x, shared["ln1"], cfg.rms_eps),
                                        c_u["attn"], pos)
                x = x + h
                x = x + L.mlp(shared["mlp"], L.rms_norm(x, shared["ln2"], cfg.rms_eps),
                              cfg.activation)
                return x, {"mamba": m_new, "attn": a_new}
            x, c_new = jax.lax.scan(body, x, (p_stack, c_stack))
        else:
            raise ValueError(seg.kind)
        new_cache[f"seg_{i}"] = c_new

    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


# ---------------------------------------------------------------------------
# fused prefill: one dispatch writes the whole prompt into the cache
# ---------------------------------------------------------------------------

def _seq_write(buf, ent, window: int):
    """Write full-seq attention entries into a decode cache buffer.

    buf: [stack..., B, T, ...] (seq at axis 2); ent: [stack..., B, S, ...].
    Non-windowed buffers take positions 0..S-1 directly; windowed ring
    buffers keep the last min(S, T) positions at slot = pos % T, exactly
    where ``gqa_decode`` would have left them after S sequential steps.
    """
    T, S = buf.shape[2], ent.shape[2]
    if not window and S > T:
        raise ValueError(f"prompt length {S} exceeds cache length {T}")
    keep = min(S, T)
    slots = np.arange(S - keep, S) % T
    return buf.at[:, :, slots].set(ent[:, :, S - keep:].astype(buf.dtype))


def _write_entries(cfg, seg: Segment, bufs, ent, S: int):
    w = cfg.attention.window
    if seg.kind in ("dense", "moe"):
        return {k: _seq_write(bufs[k], ent[k], w) for k in bufs}
    if seg.kind == "rwkv":
        return {k: ent[k].astype(bufs[k].dtype) for k in bufs}
    if seg.kind == "hybrid":
        return {"mamba": {k: ent["mamba"][k].astype(bufs["mamba"][k].dtype)
                          for k in bufs["mamba"]},
                "attn": {k: _seq_write(bufs["attn"][k], ent["attn"][k], w)
                         for k in bufs["attn"]}}
    raise ValueError(seg.kind)


def prefill_cache(cfg: ModelConfig, params, tokens, cache, prefix_embed=None):
    """Fused prefill: ONE dispatch computes the full-sequence logits AND
    writes the whole prompt's KV/state into the decode cache — replaces
    the O(prompt_len)-dispatch teacher-forced loop (ISSUE 8 satellite).

    tokens: [B,S] with B matching the cache batch dim.  Returns
    (logits [B,S,V], cache') positioned so ``decode_step`` continues at
    pos = S.  Attention families write per-position K/V (windowed ring
    buffers get the last ``window`` positions); recurrent families
    (rwkv / mamba) replace their O(1) states with the final-position
    state the full-sequence forward already computes.
    """
    x = embed_inputs(cfg, params, tokens, prefix_embed)
    S = x.shape[1]
    positions = jnp.arange(S)
    new_cache: dict = {}
    for i, seg in enumerate(segments(cfg)):
        x, _, ent = _run_segment(cfg, seg, params[f"seg_{i}"],
                                 params.get("shared_attn"), x, positions,
                                 collect_cache=True)
        new_cache[f"seg_{i}"] = _write_entries(cfg, seg, cache[f"seg_{i}"], ent, S)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache
