"""Parameter definition machinery.

Every model declares a pytree of ``ParamDef`` (shape + logical axes +
init).  From one def-tree we derive:
  * ``init_params``      — materialized arrays (smoke tests, examples)
  * ``abstract_params``  — ShapeDtypeStruct stand-ins (dry-run, no alloc)
  * ``pspec_tree``       — PartitionSpec per leaf, respecting mesh-axis
                           divisibility (non-divisible dims replicate)

Logical axis names used by the zoo:
  vocab, embed (d_model), ff, heads, kv, hd, qlora, kvlora, experts,
  layers / units / sub (stack axes, never sharded), state, conv, inner,
  classes, None (replicated).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple                 # logical axis name (or None) per dim
    init: str = "normal"        # normal | zeros | ones | embed | conv
    scale: float = 1.0          # stddev multiplier for "normal"

    def __repr__(self):  # keep pytree prints short
        return f"ParamDef{self.shape}"


# Logical axis -> preferred mesh axes, in priority order.  "fsdp" is the
# worker/data axes used for fully-sharded params in blocked mode.
TENSOR_RULES = {
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    "kv": "model",
    "experts": "model",
    "inner": "model",           # mamba2 d_inner
}
# Secondary (FSDP) eligible axes: large replicated dims we may shard over
# the worker axes when fsdp=True.
FSDP_ELIGIBLE = ("embed", "ff_in", "vocab", "ff", "inner")
STACK_AXES = ("layers", "units", "sub")


def is_param_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=is_param_def)


def _init_one(key, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    # fan-in scaled normal
    fan_in = d.shape[0] if len(d.shape) == 1 else int(np.prod(d.shape[:-1])) // (
        int(np.prod([s for s, a in zip(d.shape, d.axes) if a in STACK_AXES])) or 1)
    fan_in = max(fan_in, 1)
    std = d.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, d.shape) * std).astype(dtype)


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(defs, dtype=jnp.bfloat16):
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_param_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def _spec_for(d: ParamDef, mesh_shape: dict, fsdp_axes: Sequence[str] = (),
              tp: bool = True) -> P:
    """PartitionSpec for one leaf.

    Primary: first dim whose logical axis maps to 'model' and divides
    (skipped when ``tp`` is False).
    FSDP: if ``fsdp_axes`` given, additionally shard the largest
    remaining eligible dim over the (flattened) worker axes.
    """
    n_model = mesh_shape.get("model", 1) if tp else 1
    entries: list = [None] * len(d.shape)
    used_model = False
    for i, (s, a) in enumerate(zip(d.shape, d.axes)):
        if used_model or a is None or a in STACK_AXES or n_model <= 1:
            continue
        if TENSOR_RULES.get(a) == "model" and s % n_model == 0 and s >= n_model:
            entries[i] = "model"
            used_model = True
    if fsdp_axes:
        n_fsdp = int(np.prod([mesh_shape[a] for a in fsdp_axes]))
        if n_fsdp <= 1:
            return P(*entries)
        # largest remaining dim that divides
        cands = [
            (s, i) for i, (s, a) in enumerate(zip(d.shape, d.axes))
            if entries[i] is None and a not in STACK_AXES and a is not None
            and s % n_fsdp == 0 and s >= n_fsdp
        ]
        if cands:
            _, i = max(cands)
            entries[i] = tuple(fsdp_axes) if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*entries)


def pspec_tree(defs, mesh, fsdp: bool = False, tp: bool = True):
    """PartitionSpec pytree for a def-tree on ``mesh``.

    fsdp=True additionally shards a secondary dim over the worker axes.
    tp=False drops the tensor-parallel 'model' entries and widens the
    FSDP worker set to EVERY mesh axis — the blocked scope's layout,
    where the whole step is one full-manual shard_map and the 'model'
    axis acts as extra FSDP workers (see launch.mesh.worker_axes).
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    worker_axes = tuple(a for a in mesh.axis_names
                        if not tp or a != "model")
    fsdp_axes = worker_axes if fsdp else ()
    return tree_map_defs(lambda d: _spec_for(d, mesh_shape, fsdp_axes, tp),
                         defs)


def shardings_tree(defs, mesh, fsdp: bool = False, tp: bool = True):
    from jax.sharding import NamedSharding
    specs = pspec_tree(defs, mesh, fsdp, tp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def shard_hint(x, spec: Optional[P]):
    """with_sharding_constraint that no-ops when no mesh is active or the
    spec does not divide (keeps smoke tests on 1 device trivial)."""
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([sizes.get(a, 1) for a in names]))
            if any(a not in sizes for a in names) or x.shape[dim] % n != 0:
                return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
