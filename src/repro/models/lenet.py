"""LeNet-5 (LeCun et al., 1998) in pure JAX — the paper's experiment model.

conv(6,5x5) -> avgpool -> conv(16,5x5) -> avgpool -> fc120 -> fc84 -> fc10
on 28x28 single-channel images (FashionMNIST geometry).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.lenet_fmnist import LeNetConfig
from .params import ParamDef


def lenet_defs(cfg: LeNetConfig) -> dict:
    c1, c2 = cfg.conv_channels
    f1, f2 = cfg.fc_dims
    # 28 -> conv5 'SAME' 28 -> pool 14 -> conv5 'VALID' 10 -> pool 5
    flat = c2 * 5 * 5
    return {
        "conv1_w": ParamDef((5, 5, 1, c1), (None, None, None, None)),
        "conv1_b": ParamDef((c1,), (None,), init="zeros"),
        "conv2_w": ParamDef((5, 5, c1, c2), (None, None, None, None)),
        "conv2_b": ParamDef((c2,), (None,), init="zeros"),
        "fc1_w": ParamDef((flat, f1), (None, None)),
        "fc1_b": ParamDef((f1,), (None,), init="zeros"),
        "fc2_w": ParamDef((f1, f2), (None, None)),
        "fc2_b": ParamDef((f2,), (None,), init="zeros"),
        "out_w": ParamDef((f2, cfg.n_classes), (None, None)),
        "out_b": ParamDef((cfg.n_classes,), (None,), init="zeros"),
    }


def _pool(x):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID") / 4.0


def lenet_forward(p, images):
    """images [B,28,28,1] -> logits [B,10]."""
    x = jax.lax.conv_general_dilated(
        images, p["conv1_w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["conv1_b"]
    x = _pool(jnp.tanh(x))
    x = jax.lax.conv_general_dilated(
        x, p["conv2_w"], (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["conv2_b"]
    x = _pool(jnp.tanh(x))
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(x @ p["fc1_w"] + p["fc1_b"])
    x = jnp.tanh(x @ p["fc2_w"] + p["fc2_b"])
    return x @ p["out_w"] + p["out_b"]


def lenet_loss(p, batch):
    logits = lenet_forward(p, batch["images"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    return -jnp.mean(ll)


def lenet_accuracy(p, images, labels):
    return jnp.mean(jnp.argmax(lenet_forward(p, images), -1) == labels)
