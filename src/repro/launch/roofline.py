"""Roofline-term derivation from dry-run compile artifacts.

Hardware model: TPU v5e —
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.

All inputs are PER-DEVICE quantities: ``compiled.cost_analysis()`` of an
SPMD executable describes the per-device partitioned module, and
``hlo_stats.collective_bytes`` sums per-device ring-algorithm traffic
(post-partitioning HLO shapes are per-device).  So

  compute term    = flops_per_device / PEAK_FLOPS
  memory term     = bytes_per_device / HBM_BW
  collective term = coll_bytes_per_device / LINK_BW

and the dominant term estimates the step time lower bound on that mesh.
"""
from __future__ import annotations

import numpy as np

from .hlo_stats import dtype_bytes  # noqa: F401  (canonical table —
#   re-exported so roofline consumers stop growing private dtype maps;
#   hlo_stats.DTYPE_BYTES is the ONE place byte widths live)

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link (one link assumed serial)


def active_params(cfg) -> int:
    """Parameter count that touches every token (MoE: shared + top-k of
    the routed experts + non-expert weights)."""
    from ..models import transformer as TF
    from ..models.params import count_params, is_param_def
    import jax

    defs = TF.param_defs(cfg)
    total = count_params(defs)
    if not cfg.is_moe:
        return total
    # routed-expert leaves carry an "experts" logical axis
    moe = cfg.moe
    routed = sum(
        int(np.prod(d.shape))
        for d in jax.tree.leaves(defs, is_leaf=is_param_def)
        if is_param_def(d) and "experts" in d.axes)
    active_routed = routed * moe.top_k / max(moe.n_experts, 1)
    return int(total - routed + active_routed)


def model_flops(cfg, shape) -> float:
    """6·N_active·T for training (fwd+bwd), 2·N_active·T forward-only."""
    n = active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def derive_terms(flops_per_dev: float, bytes_per_dev: float,
                 coll_bytes_per_dev: float, chips: int,
                 model_fl: float) -> dict:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    coll_s = coll_bytes_per_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    total_flops = flops_per_dev * chips
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "bound_s": terms[dom],
        "model_flops": model_fl,
        "hlo_flops_total": total_flops,
        "useful_ratio": (model_fl / total_flops) if total_flops else 0.0,
        # fraction of roofline: useful model flops per second at the bound
        # vs the mesh's peak.
        "mfu_bound": (model_fl / max(terms[dom], 1e-30)) /
                     (chips * PEAK_FLOPS) if terms[dom] else 0.0,
    }
