import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input-shape × mesh)
lowers AND compiles on the production mesh, and harvest the roofline
inputs (cost_analysis FLOPs/bytes, collective bytes parsed from the
post-SPMD HLO, memory_analysis) without allocating a single real array.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count on first init.  This module is the only place the 512
placeholder devices exist; tests/benches see the real single device.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every case, subprocess-isolated
  python -m repro.launch.dryrun --summary        # table from recorded JSONs
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# single-case runner (imports jax lazily, after the XLA_FLAGS line)
# ---------------------------------------------------------------------------

def run_case(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, hlo_dir=None,
             hlo_name: str = "", lower_only: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import ByzantineConfig, TrainConfig, get_config, get_shape
    from ..models import params as PM
    from ..models import transformer as TF
    from ..serving.engine import build_serve_step
    from ..training.step import build_train_step
    from .hlo_stats import collective_bytes
    from .mesh import make_production_mesh
    from .roofline import derive_terms, model_flops
    from .specs import (decode_inputs, key_struct, prefill_inputs,
                        train_batch_used, train_inputs, variant_for_shape)

    overrides = overrides or {}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    shape = get_shape(shape_name)
    cfg = variant_for_shape(get_config(arch), shape)
    # "model.<path>=<int|float|str>" overrides nest into the ModelConfig,
    # e.g. --set model.rwkv.chunk=32 or --set model.attention.window=1024
    import dataclasses as _dc

    def _set_path(obj, path, value):
        head, *tail = path
        cur = getattr(obj, head)
        if tail:
            cur = _set_path(cur, tail, value)
        else:
            old = getattr(obj, head)
            if old is not None and not isinstance(old, str):
                value = type(old)(float(value)) if isinstance(old, float) \
                    else type(old)(value)
            cur = value
        return _dc.replace(obj, **{head: cur})

    model_ovr = {k: v for k, v in overrides.items() if k.startswith("model.")}
    for k, v in model_ovr.items():
        cfg = _set_path(cfg, k.split(".")[1:], v)
        overrides.pop(k)
    pdtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def structs(defs, specs, dtype):
        return jax.tree.map(
            lambda d, s: jax.ShapeDtypeStruct(
                d.shape, dtype, sharding=NamedSharding(mesh, s)),
            defs, specs,
            is_leaf=lambda x: isinstance(x, PM.ParamDef))

    defs = TF.param_defs(cfg)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "mode": shape.mode,
           "params": PM.count_params(defs), **overrides}

    t0 = time.time()
    if shape.mode == "train":
        tcfg = TrainConfig(model=cfg, byzantine=ByzantineConfig(),
                           optimizer="adamw",
                           **{k: v for k, v in overrides.items()
                              if k in ("agg_scope", "agg_layout", "remat")})
        bundle = build_train_step(tcfg, mesh)
        rec.update(scope=bundle.scope, layout=bundle.layout,
                   batch_used=train_batch_used(shape, mesh, bundle.scope))
        p_structs = structs(defs, bundle.param_specs, pdtype)
        f32 = jnp.float32
        o_structs = {"m": structs(defs, bundle.param_specs, f32),
                     "v": structs(defs, bundle.param_specs, f32)}
        batch = train_inputs(cfg, shape, mesh, scope=bundle.scope)
        step_s = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = bundle.step_fn.lower(p_structs, o_structs, batch,
                                       step_s, key_struct())
    else:
        bundle = build_serve_step(cfg, shape, mesh)
        p_structs = structs(defs, bundle.param_specs, pdtype)
        if shape.mode == "prefill":
            batch = prefill_inputs(cfg, shape, mesh)
            lowered = bundle.prefill_fn.lower(p_structs, batch)
        else:
            cache, token, pos = decode_inputs(cfg, shape, mesh,
                                              bundle.cache_spec_tree)
            lowered = bundle.decode_fn.lower(p_structs, cache, token, pos)
    rec["lower_s"] = round(time.time() - t0, 2)

    if lower_only:
        # CI smoke mode: lowering alone already runs shard_map's manual
        # lowering and the SPMD sharding annotations — the failure modes
        # this repo has hit (PartitionId / IsManualSubgroup) surface at
        # compile, so smoke callers should still prefer a full compile
        # when time allows; --lower-only exists for giant configs whose
        # CPU compile exceeds CI budgets.
        rec["hlo_lines"] = lowered.as_text().count("\n")
        if hlo_dir is not None:
            # persist the UNOPTIMIZED pre-SPMD HLO (".lowered" suffix —
            # distinct from the compiled "<cid>.txt.gz" the full run
            # saves, so --rescore keeps its post-SPMD semantics) for
            # `python -m repro.launch.lint --hlo` / hlo_stats re-analysis
            # without re-lowering
            import gzip

            from ..analysis.hlo import lower_to_hlo_text
            hlo_dir.mkdir(parents=True, exist_ok=True)
            path = hlo_dir / f"{hlo_name}.lowered.txt.gz"
            with gzip.open(path, "wt") as f:
                f.write(lower_to_hlo_text(lowered))
            rec["hlo_path"] = str(path)
            rec["hlo_format"] = "hlo-unoptimized"
        rec["ok"] = True
        rec["lower_only"] = True
        return rec

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    # ---- cost analysis (cross-check only: XLA counts while bodies ONCE,
    # so scans over L layers under-report by ~L; the authoritative numbers
    # come from hlo_stats.module_stats which multiplies trip counts) ----
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    rec["xla_flops_body_once"] = float(ca.get("flops", 0.0))
    rec["xla_bytes_body_once"] = float(ca.get("bytes accessed", 0.0))

    # ---- memory analysis (not implemented on all backends) ----
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                rec[k] = int(v)
        if ("argument_size_in_bytes" in rec and "temp_size_in_bytes" in rec):
            rec["peak_bytes_per_dev"] = (
                rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"]
                + rec.get("output_size_in_bytes", 0)
                - rec.get("alias_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    # ---- flops / bytes / collective traffic from post-SPMD HLO ----
    from .hlo_stats import module_stats
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    if hlo_dir is not None:
        import gzip
        hlo_dir.mkdir(parents=True, exist_ok=True)
        with gzip.open(hlo_dir / f"{hlo_name}.txt.gz", "wt") as f:
            f.write(hlo)
    stats = module_stats(hlo)
    flops = stats["flops"]
    nbytes = stats["bytes"]
    rec["hlo_flops_per_dev"] = flops
    rec["hlo_bytes_per_dev"] = nbytes
    coll = stats["collectives"]
    rec["collective_bytes_per_dev"] = coll.pop("total", 0.0)
    rec["collective_detail"] = {k: v for k, v in coll.items() if v}
    rec["unknown_trip_whiles"] = stats["unknown_trip_whiles"]
    rec["hlo_lines"] = hlo.count("\n")

    # ---- roofline terms ----
    # blocked scope can inflate the batch to one sequence per worker
    # (train_batch_used > shape.global_batch): scale the useful-flops
    # reference to the batch the step actually runs, or useful_ratio /
    # compute_s read ~batch_used/global_batch off
    mf = model_flops(cfg, shape)
    if shape.mode == "train":
        mf *= rec["batch_used"] / shape.global_batch
    rec["roofline"] = derive_terms(
        flops, nbytes, rec["collective_bytes_per_dev"], chips, mf)
    rec["ok"] = True
    return rec


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------

def case_id(arch, shape, mesh, tag=""):
    t = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh}{t}"


def all_cases(meshes=("single", "multi")):
    from ..configs import ARCHS, SHAPES
    for mesh in meshes:
        for arch in ARCHS:
            for shape in SHAPES:
                yield arch, shape, mesh


def run_all(out: pathlib.Path, meshes, timeout: int, skip_done: bool):
    out.mkdir(parents=True, exist_ok=True)
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    failures = []
    cases = list(all_cases(meshes))
    for i, (arch, shape, mesh) in enumerate(cases):
        cid = case_id(arch, shape, mesh)
        f = out / f"{cid}.json"
        if skip_done and f.exists():
            try:
                if json.loads(f.read_text()).get("ok"):
                    print(f"[{i+1}/{len(cases)}] {cid} cached", flush=True)
                    continue
            except Exception:
                pass
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh, "--out", str(out)],
            env=env, capture_output=True, text=True, timeout=timeout)
        dt = time.time() - t0
        status = "ok" if proc.returncode == 0 else "FAIL"
        print(f"[{i+1}/{len(cases)}] {cid} {status} ({dt:.0f}s)", flush=True)
        if proc.returncode != 0:
            failures.append(cid)
            tail = (proc.stderr or proc.stdout or "").strip().splitlines()[-25:]
            f.write_text(json.dumps(
                {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
                 "error": "\n".join(tail)}, indent=1))
            print("\n".join("   " + t for t in tail[-8:]), flush=True)
    print(f"\ndone: {len(cases) - len(failures)}/{len(cases)} ok")
    if failures:
        print("failed:", *failures, sep="\n  ")
    return 1 if failures else 0


def rescore(out: pathlib.Path):
    """Re-derive flops/bytes/collectives/roofline from the saved HLO of
    every recorded case (accounting changes without recompiling)."""
    import gzip

    from ..configs import get_config, get_shape
    from .hlo_stats import module_stats
    from .roofline import derive_terms, model_flops
    from .specs import variant_for_shape

    n = 0
    for f in sorted(out.glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        h = out / "hlo" / f"{f.stem}.txt.gz"
        if not h.exists():
            continue
        with gzip.open(h, "rt") as fh:
            stats = module_stats(fh.read())
        rec["hlo_flops_per_dev"] = stats["flops"]
        rec["hlo_bytes_per_dev"] = stats["bytes"]
        coll = stats["collectives"]
        rec["collective_bytes_per_dev"] = coll.pop("total", 0.0)
        rec["collective_detail"] = {k: v for k, v in coll.items() if v}
        rec["unknown_trip_whiles"] = stats["unknown_trip_whiles"]
        shape = get_shape(rec["shape"])
        cfg = variant_for_shape(get_config(rec["arch"]), shape)
        mf = model_flops(cfg, shape)
        if shape.mode == "train" and rec.get("batch_used"):
            mf *= rec["batch_used"] / shape.global_batch
        rec["roofline"] = derive_terms(
            stats["flops"], stats["bytes"], rec["collective_bytes_per_dev"],
            rec["chips"], mf)
        f.write_text(json.dumps(rec, indent=1, default=str))
        n += 1
    print(f"rescored {n} cases")
    return 0


def summary(out: pathlib.Path):
    rows = []
    for f in sorted(out.glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            rows.append((f.stem, "FAIL", "", "", "", "", ""))
            continue
        if r.get("lower_only"):
            rows.append((f.stem, r["mode"], "", "", "", "lower-only", ""))
            continue
        rl = r["roofline"]
        rows.append((
            f.stem, r["mode"],
            f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
            f"{rl['collective_s']:.3e}", rl["dominant"],
            f"{rl['useful_ratio']:.2f}"))
    w = [max(len(r[i]) for r in rows) for i in range(7)]
    hdr = ("case", "mode", "compute_s", "memory_s", "coll_s", "dom", "useful")
    print("  ".join(h.ljust(x) for h, x in zip(hdr, w)))
    for r in rows:
        print("  ".join(c.ljust(x) for c, x in zip(r, w)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--no-skip", action="store_true")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--rescore", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--lower-only", action="store_true",
                    help="stop after lowering (CI smoke for giant configs)")
    ap.add_argument("--set", action="append", default=[],
                    help="override TrainConfig field, e.g. agg_layout=a2a")
    args = ap.parse_args()

    if args.rescore:
        return rescore(args.out)
    if args.summary:
        summary(args.out)
        return 0
    if args.all:
        return run_all(args.out, args.meshes.split(","), args.timeout,
                       not args.no_skip)

    overrides = dict(kv.split("=", 1) for kv in args.set)
    try:
        rec = run_case(args.arch, args.shape, args.mesh, overrides,
                       hlo_dir=args.out / "hlo",
                       hlo_name=case_id(args.arch, args.shape, args.mesh,
                                        args.tag),
                       lower_only=args.lower_only)
    except Exception:
        traceback.print_exc()
        return 1
    args.out.mkdir(parents=True, exist_ok=True)
    f = args.out / f"{case_id(args.arch, args.shape, args.mesh, args.tag)}.json"
    f.write_text(json.dumps(rec, indent=1, default=str))
    if args.lower_only:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "chips", "lower_s",
                           "hlo_lines", "hlo_path") if k in rec}, indent=1))
        return 0
    rl = rec["roofline"]
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "chips", "lower_s",
                       "compile_s", "hlo_flops_per_dev", "hlo_bytes_per_dev",
                       "collective_bytes_per_dev")}, indent=1))
    print(f"roofline: compute={rl['compute_s']:.3e}s memory={rl['memory_s']:.3e}s "
          f"collective={rl['collective_s']:.3e}s dominant={rl['dominant']} "
          f"useful={rl['useful_ratio']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
