"""Mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant)
so importing this module does not touch jax device state.  The dry-run
launcher sets XLA_FLAGS for 512 host devices *before* importing jax;
tests and benches see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    from ..compat import make_mesh as _make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (tests use e.g. (4,2))."""
    from ..compat import make_mesh as _make_mesh
    return _make_mesh(shape, axes)


def worker_axes(mesh, scope: str = "global") -> Tuple[str, ...]:
    """Mesh axes that index Byzantine workers for the given agg scope.

    ``global`` (and the serving paths): every axis except the
    tensor-parallel 'model' axis — the model axis stays a GSPMD-auto /
    full-manual *dimension* axis, never a worker identity.

    ``blocked``: EVERY mesh axis.  The blocked/FSDP scope runs the whole
    step as one full-manual shard_map (XLA's partial-manual subgroups
    only support reduce-type collectives — DESIGN.md §Mesh), and its
    per-layer barrier re-gathers each bucket's params anyway, so a
    'model' axis buys nothing as tensor parallelism there; it is folded
    into the FSDP worker set instead (ZeRO-3-style: more workers, finer
    param shards).
    """
    if scope == "blocked":
        return tuple(mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a != "model")


def n_workers(mesh, scope: str = "global") -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in worker_axes(mesh, scope)]))
