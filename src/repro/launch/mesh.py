"""Mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant)
so importing this module does not touch jax device state.  The dry-run
launcher sets XLA_FLAGS for 512 host devices *before* importing jax;
tests and benches see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    from ..compat import make_mesh as _make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (tests use e.g. (4,2))."""
    from ..compat import make_mesh as _make_mesh
    return _make_mesh(shape, axes)


def worker_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_workers(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in worker_axes(mesh)]))
