import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Collective-contract lint CLI: trace the (aggregator × layout × mesh)
matrix, check every contract against the rule registry, and keep the
BENCH_contracts.json bytes envelope honest.

The XLA_FLAGS line above MUST run before any jax import — the lint
meshes (analysis.matrix.LINT_MESHES) need 8 host devices and jax locks
the device count on first init.  Everything is make_jaxpr tracing; no
compile, no execution, cheap on CPU.

Usage:
  python -m repro.launch.lint --all               # full matrix, lint only
  python -m repro.launch.lint --all --record      # + write BENCH_contracts.json
  python -m repro.launch.lint --case brsgd gather flat
  python -m repro.launch.lint --selftest          # seeded violations fire?
  python -m repro.launch.lint --hlo lowered.txt[.gz]   # lint an HLO dump

Mesh families default to both (flat, dm); REPRO_TEST_MESHES or
--meshes restricts, so CI splits the matrix exactly like the tier-1
jobs.  Exit code 1 on any violation.
"""
import argparse
import gzip
import json
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_CONTRACTS = REPO_ROOT / "BENCH_contracts.json"
CONTRACTS_SCHEMA = 1


def load_budgets(path) -> dict:
    """BENCH_contracts.json -> {case_key: case record} (empty if the
    file doesn't exist yet — bytes-budget checks then skip)."""
    path = pathlib.Path(path)
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    from ..analysis.matrix import case_key
    return {case_key(c["aggregator"], c["layout"], c["mesh"]): c
            for c in data.get("cases", ())}


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() \
            or "unknown"
    except Exception:
        return "unknown"


def write_contracts(path, records, meshes) -> None:
    import datetime

    import jax

    from ..analysis.matrix import LINT_ARCH
    out = {
        "schema": CONTRACTS_SCHEMA,
        "kind": "contracts",
        "meta": {
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "git_rev": _git_rev(),
            "date": datetime.date.today().isoformat(),
            "arch": f"{LINT_ARCH} (reduced)",
            "meshes": list(meshes),
            "note": "per-step collective payload bytes per "
                    "(aggregator x layout x mesh), traced by "
                    "repro.analysis; regenerate with "
                    "`python -m repro.launch.lint --all --record`",
        },
        "cases": records,
    }
    pathlib.Path(path).write_text(json.dumps(out, indent=1) + "\n")


def _report(violations) -> None:
    for v in violations:
        print(v.format(), file=sys.stderr)
    print(f"lint: {len(violations)} violation(s)", file=sys.stderr)


def lint_hlo_file(path) -> int:
    """Lint a persisted HLO dump (dryrun --lower-only / sweep output)
    with the IR-agnostic rules — no case context, so count/axis rules
    don't apply, but the contract summary is printed for inspection."""
    from ..analysis import hlo as ahlo
    from ..analysis.rules import RuleContext, run_rules
    opener = gzip.open if str(path).endswith(".gz") else open
    with opener(path, "rt") as f:
        text = f.read()
    contract = ahlo.extract(text, meta={"ir": "hlo", "path": str(path)})
    print(json.dumps(contract.summary(), indent=1))
    vs = run_rules(contract, RuleContext(case=str(path)))
    if vs:
        _report(vs)
    return 1 if vs else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="static collective-contract lint over the "
                    "(aggregator x layout x mesh) matrix")
    ap.add_argument("--all", action="store_true",
                    help="lint the full matrix (default when no mode given)")
    ap.add_argument("--case", nargs=3,
                    metavar=("AGG", "LAYOUT", "MESH"),
                    help="one case, e.g. --case brsgd gather flat "
                         "(MESH 'none' for the local layout)")
    ap.add_argument("--meshes",
                    help="comma list of mesh families (default: "
                         "REPRO_TEST_MESHES or all)")
    ap.add_argument("--record", action="store_true",
                    help="write the traced contracts to --contracts")
    ap.add_argument("--contracts", default=str(DEFAULT_CONTRACTS),
                    help="bytes-envelope file (default: repo "
                         "BENCH_contracts.json)")
    ap.add_argument("--budget-factor", type=float, default=2.0,
                    help="allowed drift vs the recorded envelope")
    ap.add_argument("--selftest", action="store_true",
                    help="verify every shipped rule fires on its seeded "
                         "broken toy")
    ap.add_argument("--hlo", metavar="FILE",
                    help="lint a persisted HLO text dump instead")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.hlo:
        return lint_hlo_file(args.hlo)

    from ..analysis import matrix

    if args.selftest:
        failures = matrix.run_selftest(matrix.mesh_names())
        for f in failures:
            print(f"selftest: {f}", file=sys.stderr)
        print("lint selftest: "
              + ("FAIL" if failures else "every shipped rule fires OK"))
        return 1 if failures else 0

    meshes = ([m.strip() for m in args.meshes.split(",") if m.strip()]
              if args.meshes else matrix.mesh_names())

    if args.case:
        agg, layout, mesh_name = args.case
        budgets = load_budgets(args.contracts)
        contract, ctx = matrix.trace_case(
            agg, layout, mesh_name if layout != "local" else "none",
            budgets=budgets, budget_factor=args.budget_factor)
        print(f"{ctx.case}: {json.dumps(contract.summary())}")
        from ..analysis.rules import run_rules
        vs = run_rules(contract, ctx)
        if vs:
            _report(vs)
        return 1 if vs else 0

    # full matrix (--all, and the default mode)
    budgets = {} if args.record else load_budgets(args.contracts)

    def progress(case, contract, vs):
        if not args.quiet:
            s = contract.summary()
            mark = "FAIL" if vs else "ok"
            print(f"  {case:<28} {mark:<4} "
                  f"collective_bytes={s['collective_bytes']:.0f}",
                  flush=True)

    records, violations = matrix.run_matrix(
        meshes, budgets=budgets, budget_factor=args.budget_factor,
        progress=progress)
    if args.record:
        write_contracts(args.contracts, records, meshes)
        print(f"recorded {len(records)} contracts -> {args.contracts}")
    if violations:
        _report(violations)
        return 1
    print(f"lint: {len(records)} cases clean over meshes {meshes}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
