"""Real training driver: distributed BrSGD on an actual device mesh.

On the CPU container this runs reduced configs on a small host-device
mesh (set JAX_NUM_CPU_DEVICES or XLA_FLAGS before launch to get more
than one device); on a TPU pod the same driver runs the full config on
``make_production_mesh()``.

  PYTHONPATH=src JAX_NUM_CPU_DEVICES=8 python -m repro.launch.train \
      --arch qwen3-0.6b --reduced --steps 20 --attack gaussian --alpha 0.25
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np


def build_mesh(spec: str | None):
    import jax
    from .mesh import make_mesh, make_production_mesh
    n = len(jax.devices())
    if spec == "production":
        return make_production_mesh()
    if spec:
        shape = tuple(int(x) for x in spec.split("x"))
        return make_mesh(shape, ("data", "model")[:len(shape)] if len(shape) <= 2
                         else ("pod", "data", "model"))
    # default: as much data-parallel as the host offers
    model = 2 if n % 2 == 0 and n > 2 else 1
    return make_mesh((n // model, model), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-per-worker", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2, or 'production'")
    ap.add_argument("--aggregator", default="brsgd",
                    help="any rule registered in core.engine "
                         "(validated after parse, when jax loads)")
    ap.add_argument("--attack", default="none",
                    help="'none' or any attack registered in core.threat "
                         "(validated after parse, when jax loads; the "
                         "error message lists the live registry)")
    ap.add_argument("--alpha", type=float, default=0.0)
    ap.add_argument("--membership", default="prefix",
                    choices=["prefix", "random", "resample"],
                    help="byzantine-membership policy (core.threat)")
    ap.add_argument("--quorum", type=int, default=0,
                    help="fire aggregation once this many workers have "
                         "arrived (0 = synchronous full round); opts the "
                         "step into the elastic path (DESIGN.md §Elastic)")
    ap.add_argument("--straggle", default="none",
                    help="arrival-delay distribution dist[:scale], dist in "
                         "none|exp|pareto — e.g. 'exp:0.5' (data.pipeline."
                         "ArrivalSchedule)")
    ap.add_argument("--supervise", action="store_true",
                    help="run under the recovery supervisor (DESIGN.md "
                         "§Faults): in-step finite/spike guard, worker "
                         "eviction, bounded rollback to last_good.  "
                         "Implies the elastic path (quorum defaults to "
                         "the full worker count)")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--agg-layout", default="auto")
    ap.add_argument("--agg-scope", default="auto")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save an (atomic) checkpoint every N steps into "
                         "--ckpt-dir; 0 = final step only.  A serving "
                         "HotSwapper polling the same directory hot-swaps "
                         "each one live (DESIGN.md §Serve)")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    import dataclasses

    from ..checkpoint import ckpt
    from ..configs import (ByzantineConfig, RecoveryConfig, TrainConfig,
                           get_config)
    from ..core import engine, threat
    from ..data.pipeline import (ArrivalSchedule, LMWorkerPipeline,
                                 parse_straggle)
    from ..faults import Supervisor
    from ..launch.mesh import n_workers
    from ..models import params as PM
    from ..models import transformer as TF
    from ..serving import telemetry
    from ..training.step import build_train_step, resolve_strategy

    if args.aggregator not in engine.registered():
        ap.error(f"--aggregator {args.aggregator!r}: "
                 f"choose from {', '.join(engine.registered())}")
    if args.attack != "none" and args.attack not in threat.registered():
        ap.error(f"--attack {args.attack!r}: choose from none, "
                 f"{', '.join(threat.registered())}")
    try:
        straggle, straggle_scale = parse_straggle(args.straggle)
    except ValueError as e:
        ap.error(f"--straggle {args.straggle!r}: {e}")
    mesh = build_mesh(args.mesh)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bcfg = ByzantineConfig(aggregator=args.aggregator, attack=args.attack,
                           alpha=args.alpha, membership=args.membership)
    tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer=args.optimizer,
                       lr=args.lr, agg_layout=args.agg_layout,
                       agg_scope=args.agg_scope, remat=args.remat)

    # elastic rounds: any of --quorum, a straggle distribution, or a
    # timing-scope attack drops the synchronous-round assumption.  The
    # worker-slot count is scope-dependent (blocked folds 'model' into
    # the worker set), so resolve the scope before sizing max_m.
    timing = (args.attack != "none"
              and threat.get_spec(args.attack).scope == "timing")
    elastic = (args.quorum > 0 or straggle != "none" or timing
               or args.supervise)
    sched = None
    if elastic:
        scope, _ = resolve_strategy(tcfg)
        m = n_workers(mesh, scope)
        quorum = args.quorum or m
        bcfg = dataclasses.replace(bcfg, max_m=m, quorum=quorum)
        tcfg = dataclasses.replace(tcfg, byzantine=bcfg)
        sched = ArrivalSchedule(m, quorum, straggle, straggle_scale,
                                byz=bcfg, seed=tcfg.seed)
    if args.supervise:
        tcfg = dataclasses.replace(tcfg,
                                   recovery=RecoveryConfig(guard=True))

    bundle = build_train_step(tcfg, mesh)
    # blocked scope folds every mesh axis (incl. 'model') into the
    # worker set, so the pipeline's worker count is scope-dependent
    m = n_workers(mesh, bundle.scope)
    print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} workers={m} "
          f"scope={bundle.scope} arch={cfg.name} "
          f"params={PM.count_params(TF.param_defs(cfg)):,}")
    psh, osh, bsh = bundle.shardings(mesh)
    key = jax.random.PRNGKey(tcfg.seed)
    params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
    if args.optimizer == "adamw":
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        opt_state = {"m": z(), "v": z()}
    elif args.optimizer == "momentum":
        opt_state = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        opt_state = ()

    pipe = LMWorkerPipeline(cfg, m, args.batch_per_worker, args.seq,
                            seed=tcfg.seed, byz=bcfg)
    sup = None
    if args.supervise:
        sup = Supervisor(bundle.step_fn, bcfg, tcfg.recovery, m,
                         ckpt_dir=args.ckpt_dir, like=params,
                         shardings=psh)
    t_start = time.time()
    history = []
    with mesh:
        for step in range(args.steps):
            batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                     for k, v in pipe.batch(step).items()}
            n_active = m
            if sup is not None:
                active = sched.active(step)
                params, opt_state, met = sup.run_step(
                    params, opt_state, batch, step,
                    jax.random.fold_in(key, step), sched_active=active)
                n_active = int(met["n_active"])
            elif sched is not None:
                active = sched.active(step)
                n_active = int(active.sum())
                params, opt_state, met = bundle.step_fn(
                    params, opt_state, batch, jnp.int32(step),
                    jax.random.fold_in(key, step), jnp.asarray(active))
            else:
                params, opt_state, met = bundle.step_fn(
                    params, opt_state, batch, jnp.int32(step),
                    jax.random.fold_in(key, step))
            if step % args.log_every == 0 or step == args.steps - 1:
                met = {k: v if isinstance(v, str) else float(v)
                       for k, v in met.items()}
                history.append({"step": step, "n_active": n_active, **met})
                act_s = f" active={n_active}/{m}" if sched is not None else ""
                print(f"step {step:4d} loss={met['loss']:.4f} "
                      f"gnorm={met['gnorm']:.3f} "
                      f"selected={met['n_selected']:.1f}/{m} "
                      f"(bucket min {met['n_selected_min']:.0f})" + act_s,
                      flush=True)
                if args.ckpt_dir:
                    # robustness telemetry beside the checkpoints: the
                    # server surfaces the aggregation stats the weights
                    # it serves were trained under (serving/telemetry)
                    telemetry.append_row(args.ckpt_dir, {
                        "step": step,
                        "gnorm": met["gnorm"],
                        "n_selected": met["n_selected"],
                        "n_selected_min": met["n_selected_min"],
                        "n_active": met["n_active"],
                        "quorum": bcfg.quorum or m,
                    })
            if (args.ckpt_dir and args.ckpt_every
                    and (step + 1) % args.ckpt_every == 0):
                if sup is not None:
                    sup.checkpoint(params, step + 1)
                else:
                    ckpt.save(args.ckpt_dir, params, step=step + 1)

    dt = time.time() - t_start
    tok = args.steps * m * args.batch_per_worker * args.seq
    print(f"done: {args.steps} steps, {dt:.1f}s, {tok/dt:.0f} tok/s")
    if sup is not None:
        s = sup.summary()
        print(f"supervisor: holds={s['holds']} evictions={s['evictions']} "
              f"rollbacks={s['rollbacks']} "
              f"quorum_shrinks={s['quorum_shrinks']} "
              f"quorum_holds={s['quorum_holds']}")
    if args.ckpt_dir:
        p = pathlib.Path(args.ckpt_dir)
        if sup is not None:
            sup.checkpoint(params, args.steps)
        else:
            ckpt.save(str(p), params, step=args.steps)
        (p / "history.json").write_text(json.dumps(history, indent=1))
        print(f"checkpoint -> {p}")
    return history


if __name__ == "__main__":
    main()
