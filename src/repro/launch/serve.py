"""Serving driver: fused one-dispatch prefill + greedy decode, and the
continuous-batching serve loop with hot-swapped checkpoints.

Single-shot (fixed batch, shared prompt length):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --prompt-len 32 --gen 16 --batch 4

Continuous batching + hot swap + /metrics (DESIGN.md §Serve):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --serve-loop --requests 8 --max-batch 4 --ckpt-dir runs/ck \
      --metrics-out metrics.txt
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def run_serve_loop(args, cfg):
    """Continuous batching over a synthetic request stream; params come
    from the newest checkpoint under --ckpt-dir (hot-swapped live) or a
    fresh init when no directory is given."""
    import jax

    from ..checkpoint import ckpt
    from ..models import params as PM
    from ..models import transformer as TF
    from ..serving import HotSwapper, ServeLoop, latest_row

    key = jax.random.PRNGKey(args.seed)
    like = PM.init_params(TF.param_defs(cfg), key)
    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.ckpt_dir:
        swapper = HotSwapper(args.ckpt_dir, like=like)
        loop = ServeLoop(cfg, args.max_batch, max_len, swapper=swapper)
        print(f"serving checkpoint step {swapper.loaded_step} "
              f"from {args.ckpt_dir}")
    else:
        loop = ServeLoop(cfg, args.max_batch, max_len, params=like)

    rng = np.random.RandomState(args.seed)
    for _ in range(args.requests):
        plen = rng.randint(max(2, args.prompt_len // 2), args.prompt_len + 1)
        loop.submit(rng.randint(0, cfg.vocab, size=plen), max_new=args.gen)
    t0 = time.time()
    done = loop.run()
    dt = time.time() - t0
    assert len(done) == args.requests, "dropped requests"
    n_tok = sum(len(v) for v in done.values())
    print(f"arch={cfg.name} requests={args.requests} "
          f"max_batch={args.max_batch} tokens={n_tok} "
          f"({n_tok / max(dt, 1e-9):.0f} tok/s) steps={loop.steps} "
          f"decode_compiles={loop.decode_compiles()}")
    if loop.swapper:
        print(f"swaps={loop.swapper.swap_count} "
              f"(serving step {loop.swapper.loaded_step})")
    train_row = latest_row(args.ckpt_dir) if args.ckpt_dir else None
    metrics = loop.metrics.render(train_row)
    print(metrics, end="")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(metrics)
        print(f"metrics -> {args.metrics_out}")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache length; default prompt+gen")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serve-loop", action="store_true",
                    help="continuous-batching scheduler instead of the "
                         "fixed-batch single shot")
    ap.add_argument("--requests", type=int, default=8,
                    help="[serve-loop] synthetic request count")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="[serve-loop] decode slot count")
    ap.add_argument("--ckpt-dir", default=None,
                    help="[serve-loop] serve (and hot-swap) checkpoints "
                         "from this directory")
    ap.add_argument("--metrics-out", default=None,
                    help="[serve-loop] write the /metrics dump here")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import params as PM
    from ..models import transformer as TF

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.serve_loop:
        return run_serve_loop(args, cfg)

    max_len = args.max_len or (args.prompt_len + args.gen)
    key = jax.random.PRNGKey(args.seed)
    params = PM.init_params(TF.param_defs(cfg), key)
    B = args.batch
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16

    prefill = jax.jit(lambda p, t, c: TF.prefill_cache(cfg, p, t, c),
                      donate_argnums=(2,))
    decode = jax.jit(lambda p, c, t, pos: TF.decode_step(cfg, p, c, t, pos),
                     donate_argnums=(1,))

    # fused prefill: ONE dispatch writes the whole prompt's KV/state
    # (the seed teacher-forced the decode step per token — O(prompt_len)
    # dispatches)
    cache = TF.init_cache(cfg, B, max_len, dtype)
    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        toks.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits.reshape(B, -1), axis=-1)[:, None].astype(jnp.int32)
    t_gen = time.time() - t0

    gen = np.stack(toks, axis=1)
    print(f"arch={cfg.name} B={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s ({B * args.prompt_len / t_prefill:.0f} tok/s, 1 dispatch)")
    print(f"decode : {t_gen:.2f}s ({B * args.gen / max(t_gen, 1e-9):.0f} tok/s)")
    print("sample tokens:", gen[0][:12].tolist())
    assert np.isfinite(np.asarray(logits)).all(), "NaN in serving logits"
    return gen


if __name__ == "__main__":
    main()
