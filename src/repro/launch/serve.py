"""Serving driver: batched prefill + greedy decode against the KV/state
cache.  Reduced configs run end-to-end on CPU; the same driver targets
``make_production_mesh()`` on a pod.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache length; default prompt+gen")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import params as PM
    from ..models import transformer as TF

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_len = args.max_len or (args.prompt_len + args.gen)
    key = jax.random.PRNGKey(args.seed)
    params = PM.init_params(TF.param_defs(cfg), key)
    B = args.batch
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)

    decode = jax.jit(lambda p, c, t, pos: TF.decode_step(cfg, p, c, t, pos),
                     donate_argnums=(1,))

    # prefill by teacher-forcing the decode step (shares the cache layout);
    # a fused full-sequence prefill is used by the dry-run serve path.
    cache = TF.init_cache(cfg, B, max_len,
                          jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompt[:, i:i + 1], jnp.int32(i))
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    tok = jnp.argmax(logits.reshape(B, -1), axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        toks.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits.reshape(B, -1), axis=-1)[:, None].astype(jnp.int32)
    t_gen = time.time() - t0

    gen = np.stack(toks, axis=1)
    print(f"arch={cfg.name} B={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s ({B * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode : {t_gen:.2f}s ({B * args.gen / max(t_gen, 1e-9):.0f} tok/s)")
    print("sample tokens:", gen[0][:12].tolist())
    assert np.isfinite(np.asarray(logits)).all(), "NaN in serving logits"
    return gen


if __name__ == "__main__":
    main()
