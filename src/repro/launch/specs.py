"""ShapeDtypeStruct input stand-ins for every (arch x shape x mesh)
combination — the dry-run lowers against these; nothing is allocated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import transformer as TF
from .mesh import n_workers, worker_axes


def key_struct():
    return jax.eval_shape(lambda: jax.random.key(0))


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def variant_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k on full-attention archs selects the sliding-window
    variant (window=8192) so decode state is O(window); MLA/SSM/hybrid
    archs keep their native sub-quadratic-state path (DESIGN.md §4)."""
    import dataclasses
    a = cfg.attention
    if (shape.name == "long_500k" and a.kind == "gqa" and a.window == 0):
        return dataclasses.replace(
            cfg, attention=dataclasses.replace(a, window=8192))
    return cfg


def train_inputs(cfg: ModelConfig, shape: InputShape, mesh,
                 scope: str = "global") -> dict:
    """Batch pytree [m, b, ...] for the worker-sharded train step.

    ``scope`` picks the worker set (blocked folds the 'model' axis into
    the workers); when the worker count exceeds the shape's global
    batch, every worker gets one sequence (the dry-run only needs
    shapes, and the real driver sizes its own batches).  Callers that
    account flops against the batch must use :func:`train_batch_used`
    — the m·b actually fed to the step, which the inflation can raise
    above ``shape.global_batch``.
    """
    m = n_workers(mesh, scope)
    assert shape.global_batch % m == 0 or shape.global_batch < m, \
        (shape.global_batch, m)
    b = max(1, shape.global_batch // m)
    waxes = worker_axes(mesh, scope)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    s_tok = shape.seq_len - cfg.n_prefix_tokens
    out = {"tokens": _sds((m, b, s_tok), jnp.int32, mesh, P(wspec))}
    if cfg.n_prefix_tokens:
        out["prefix_embed"] = _sds((m, b, cfg.n_prefix_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, P(wspec))
    return out


def train_batch_used(shape: InputShape, mesh, scope: str = "global") -> int:
    """The sequence count :func:`train_inputs` actually builds (m·b) —
    equals ``shape.global_batch`` except when the worker count exceeds
    it and every worker gets one sequence."""
    m = n_workers(mesh, scope)
    return m * max(1, shape.global_batch // m)


def prefill_inputs(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    waxes = worker_axes(mesh)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    B = shape.global_batch
    s_tok = shape.seq_len - cfg.n_prefix_tokens
    bspec = P(wspec) if B % n_workers(mesh) == 0 and B >= n_workers(mesh) else P()
    out = {"tokens": _sds((B, s_tok), jnp.int32, mesh, bspec)}
    if cfg.n_prefix_tokens:
        out["prefix_embed"] = _sds((B, cfg.n_prefix_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, bspec)
    return out


def decode_inputs(cfg: ModelConfig, shape: InputShape, mesh, cache_spec_tree):
    """(cache structs, token struct, pos).  Cache shardings follow
    serving.cache_specs."""
    B = shape.global_batch
    defs = TF.cache_defs(cfg, B, shape.seq_len)
    is_def = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
    cache = jax.tree.map(
        lambda sd, sp: _sds(sd[0], jnp.bfloat16, mesh, sp),
        defs, cache_spec_tree, is_leaf=is_def)
    waxes = worker_axes(mesh)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    bspec = P(wspec) if B % n_workers(mesh) == 0 and B >= n_workers(mesh) else P()
    token = _sds((B, 1), jnp.int32, mesh, bspec)
    pos = jnp.int32(shape.seq_len - 1)
    return cache, token, pos
