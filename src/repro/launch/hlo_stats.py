"""FLOP / HBM-byte / collective-traffic accounting from HLO text.

``compiled.cost_analysis()`` counts every while body ONCE (scans over L
layers report 1 layer of work) and reports no communication at all, so
the roofline terms are derived here instead: we walk the call graph of
the post-SPMD module, multiplying each ``while`` body by its trip count
(XLA CPU records ``backend_config={"known_trip_count":{"n":L}}``;
fallback: recover the bound from the loop condition's
``compare(..., constant)``).

Per computation we accumulate:

  flops   dot: 2 * prod(result_dims) * prod(contracting dims)
          convolution: 2 * prod(result) * prod(kernel) / out_features
          elementwise arithmetic: 1 * prod(result)  (transcendental: 6x)
  bytes   per top-level op: result bytes + operand bytes (fusions count
          at the call site only — their internals never touch HBM)
  coll    ring-algorithm per-device volume:
            all-gather          result_bytes * (G-1)/G
            all-reduce          2 * bytes * (G-1)/G
            reduce-scatter      operand_bytes * (G-1)/G
            all-to-all          bytes * (G-1)/G
            collective-permute  bytes

Post-partitioning HLO shapes are per-device, so every number here is a
PER-DEVICE quantity.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

# Canonical dtype -> bytes-per-element table for the WHOLE repo: the
# roofline terms, the analytic cost model and the byte lint all import
# it from here (one table, one module — they can never diverge).
# Sub-byte dtypes are fractional (s4/u4 pack two elements per byte);
# "token" is a zero-byte ordering artifact.
DTYPE_BYTES: dict = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5, "s2": 0.25,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5, "u2": 0.25,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}
_DTYPE_BYTES = DTYPE_BYTES          # back-compat alias

# numpy/jax spellings accepted by :func:`dtype_bytes` alongside the HLO
# short names above
_DTYPE_ALIASES = {
    "float64": "f64", "float32": "f32", "float16": "f16",
    "bfloat16": "bf16",
    "float8_e4m3": "f8e4m3", "float8_e4m3fn": "f8e4m3fn",
    "float8_e4m3fnuz": "f8e4m3fnuz",
    "float8_e4m3b11fnuz": "f8e4m3b11fnuz",
    "float8_e5m2": "f8e5m2", "float8_e5m2fnuz": "f8e5m2fnuz",
    "float8_e3m4": "f8e3m4", "float8_e8m0fnu": "f8e8m0fnu",
    "float4_e2m1fn": "f4e2m1fn",
    "int64": "s64", "int32": "s32", "int16": "s16", "int8": "s8",
    "int4": "s4", "int2": "s2",
    "uint64": "u64", "uint32": "u32", "uint16": "u16", "uint8": "u8",
    "uint4": "u4", "uint2": "u2",
    "bool": "pred", "complex64": "c64", "complex128": "c128",
}

# Tokens that LOOK like an HLO element type.  _SHAPE_RE also matches
# non-type text such as "replica_groups=[4,2]" ("groups") or
# "dimensions=[0]" — those are silently skipped; a dtype-shaped token
# missing from the table is a loud error instead of a silent byte
# undercount (it used to poison collective_bytes and the bytes-budget
# lint without any warning).
_DTYPE_LIKE_RE = re.compile(r"^(?:pred|token|bf16|[fsu]\d{1,3}\w*|c\d{2,3})$")


def register_dtype(name: str, nbytes: float) -> None:
    """Register a byte width for a dtype the table doesn't know yet
    (the escape hatch the unknown-dtype error points at)."""
    DTYPE_BYTES[str(name)] = float(nbytes)


def dtype_bytes(dtype) -> float:
    """Bytes per element of ``dtype`` — HLO short name ("bf16"),
    numpy-style name ("bfloat16"), or anything with a ``.name``/
    ``str()`` in either spelling (np.dtype, jnp dtypes)."""
    name = getattr(dtype, "name", None)
    if not isinstance(name, str):
        name = str(dtype)
    key = _DTYPE_ALIASES.get(name, name)
    if key in DTYPE_BYTES:
        return float(DTYPE_BYTES[key])
    raise KeyError(
        f"unknown dtype {name!r}: add it via "
        "repro.launch.hlo_stats.register_dtype(name, nbytes)")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = TYPE opcode(" — TYPE may be a tuple "(f32[..], /*index=5*/...)"
# (tuple types embed /*index=N*/ comments, so the type group is lazy and
# the opcode is the first " word(" occurrence after the '=').
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(.*?)\s+"
    r"([\w\-]+?)(?:-start)?\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# unoptimized (pre-SPMD) HLO — what ``Lowered.compiler_ir('hlo')``
# emits — writes bare headers with no signature: "shmap_body.38 {"
_COMP_BARE_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\{\s*$")
_CALLS_RE = re.compile(r"(?:to_apply|calls|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"\s:{]+n[\\"\s:]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_CMP_RE = re.compile(
    r"compare\(\s*%?([\w.\-]+),\s*%?([\w.\-]+)\s*\),?.*direction=(LT|LE|GT|GE)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIMLBL_RE = re.compile(r"dim_labels=(\w+)_(\w+)->(\w+)")

_ELEMWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "compare", "select", "and", "or", "xor", "not",
    "sign", "floor", "ceil", "round-nearest-afz", "clamp",
}
_ELEMWISE_6 = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
               "logistic", "cosine", "sine", "expm1", "log1p", "erf"}
# ops whose HBM traffic is proportional to the SLICE, not the operand
# buffer: dynamic-slice reads `result` bytes from the buffer;
# dynamic-update-slice reads+writes the update region (the rest of the
# buffer aliases in place on TPU).  Counting full operands here inflates
# scan-heavy models (decode caches, recurrent states) by the trip count.
_SLICE_OPS = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter",
              "slice", "pad"}
_REDUCE_OPS = {"reduce", "reduce-window"}
_SKIP_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "after-all", "iota", "while", "call",
               "conditional", "custom-call", "opt-barrier"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _dims(type_str: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            if _DTYPE_LIKE_RE.match(dt):
                raise ValueError(
                    f"HLO element type {dt!r} has no byte width in "
                    "hlo_stats.DTYPE_BYTES — byte accounting would "
                    "silently undercount; register it via "
                    "hlo_stats.register_dtype(name, nbytes)")
            continue                    # non-type token (replica_groups=...)
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dt, shape))
    return out

def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n

def _type_bytes(type_str: str) -> int:
    return sum(_nelems(s) * _DTYPE_BYTES[dt] for dt, s in _dims(type_str))


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _split_computations(text: str) -> dict:
    comps: dict = {}
    cur, name = [], None
    for line in text.splitlines():
        stripped = line.strip()
        m = None
        if ("{" in line and not stripped.startswith("HloModule")
                and "=" not in stripped.split("(", 1)[0]):
            m = (_COMP_RE.match(stripped) if "->" in line
                 else _COMP_BARE_RE.match(stripped))
        if m:
            name = m.group(1)
            cur = [line]
            comps[name] = cur
        elif stripped == "}":
            name = None
        elif name is not None:
            cur.append(line)
    return comps


def _trip_count_from_cond(cond_lines) -> int | None:
    consts = {}
    for l in cond_lines:
        m = _CONST_RE.search(l)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for l in cond_lines:
        m = _CMP_RE.search(l)
        if m:
            a, b, d = m.groups()
            c = consts.get(b, consts.get(a))
            if c is not None:
                return c + (1 if d in ("LE", "GE") else 0)
    return None


_PARAM_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+parameter\((\d+)\)")

# shape/element-preserving ops that are register-level inside a fusion —
# the slice/full-read analysis looks THROUGH them
_TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "transpose",
                "negate", "abs"}


def _fusion_table(fused_lines):
    """name -> (type, op, rest) for every op in a fused computation, plus
    the root name."""
    tab, root = {}, None
    for l in fused_lines:
        m = _OP_RE.match(l)
        if not m:
            pm = _PARAM_RE.match(l)
            if pm:
                tab[pm.group(1)] = (pm.group(2), "parameter", "")
                if l.lstrip().startswith("ROOT"):
                    root = pm.group(1)
            continue
        tab[m.group(1)] = (m.group(2), m.group(3), l[m.end():])
        if l.lstrip().startswith("ROOT"):
            root = m.group(1)
    return tab, root


def _param_read_costs(fused_lines) -> dict:
    """index -> bytes the fused kernel actually READS per parameter.

    Interior ops of a fusion are register/VMEM-level: a fusion reads a
    parameter from HBM on demand.  If every dataflow path from the
    parameter (through transparent convert/bitcast/... chains) ends in a
    slice-type op, only the slice is read; the buffer operand of a
    root dynamic-update-slice aliases in place (read ~0).  Any other
    consumer implies a full read."""
    tab, _ = _fusion_table(fused_lines)
    if not tab:
        return {}
    # uses: name -> list of (consumer op, consumer result bytes, position)
    uses: dict = {}
    for name, (rtype, op, rest) in tab.items():
        if op == "parameter":
            continue
        for pos, o in enumerate(_OPERAND_RE.findall(rest)):
            uses.setdefault(o, []).append((op, _type_bytes(rtype), pos))

    def read_cost(name, full, depth=0):
        """bytes read from HBM for value `name` of size `full`."""
        if depth > 8:
            return full
        total = 0.0
        for op, rb, pos in uses.get(name, ()):
            if op == "dynamic-update-slice" and pos == 0:
                continue                      # aliased in place
            if op in _SLICE_OPS:
                total += rb                   # slice-sized read
            elif op in _TRANSPARENT:
                # find the transparent op's own name to follow its uses
                t_names = [n for n, (t, o2, r2) in tab.items()
                           if o2 == op and name in _OPERAND_RE.findall(r2)]
                if not t_names:
                    return full
                for tn in t_names:
                    total += read_cost(tn, full, depth + 1)
            else:
                return full                   # real full-size consumer
            if total >= full:
                return full
        return min(total, full)

    out = {}
    for name, (rtype, op, _) in tab.items():
        if op != "parameter":
            continue
        pm = [l for l in fused_lines if _PARAM_RE.match(l)
              and _PARAM_RE.match(l).group(1) == name]
        idx = int(_PARAM_RE.match(pm[0]).group(3)) if pm else None
        if idx is None:
            continue
        full = _type_bytes(rtype)
        out[idx] = read_cost(name, full)
    return out


def _fusion_write_bytes(fused_lines, full_rbytes: float) -> float:
    """Bytes a fusion writes to HBM: the update-region size when the
    root is (transparently wrapped) dynamic-update-slice — the rest of
    the buffer aliases — else the result size."""
    tab, root = _fusion_table(fused_lines)
    if root is None:
        return full_rbytes

    def unwrap(name, depth=0):
        if depth > 8 or name not in tab:
            return name
        rtype, op, rest = tab[name]
        if op in _TRANSPARENT:
            ops_ = _OPERAND_RE.findall(rest)
            if len(ops_) == 1:
                return unwrap(ops_[0], depth + 1)
        return name

    def write_of(name):
        name = unwrap(name)
        if name not in tab:
            return None
        rtype, op, rest = tab[name]
        if op == "dynamic-update-slice":
            ops_ = _OPERAND_RE.findall(rest)
            if len(ops_) > 1:
                upd = unwrap(ops_[1])
                if upd in tab:
                    b = _type_bytes(tab[upd][0])
                    if b:
                        return 2.0 * b        # read-modify-write region
        return _type_bytes(rtype)

    rtype, op, rest = tab[root]
    if op == "tuple":
        parts = [write_of(o) for o in _OPERAND_RE.findall(rest)]
        parts = [p for p in parts if p]
        if parts:
            return float(sum(parts))
        return full_rbytes
    w = write_of(root)
    return float(w) if w else full_rbytes


class _Stats:
    __slots__ = ("flops", "bytes", "coll", "ops")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = defaultdict(float)
        # one record per collective INSTRUCTION (repro.analysis reads
        # these into a CollectiveContract): op name, payload bytes (NOT
        # ring volume), result type, replica-group size, and the number
        # of executions per step (while-trip multiplication)
        self.ops = []

    def add(self, other: "_Stats", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.coll.items():
            self.coll[k] += v * scale
        for rec in other.ops:
            self.ops.append({**rec, "count": rec["count"] * scale})


def module_stats(hlo_text: str) -> dict:
    """Whole-module per-device stats with while-trip multiplication."""
    comps = _split_computations(hlo_text)
    memo: Dict[str, _Stats] = {}
    notes = {"unknown_trip_whiles": 0}

    def symtab(lines) -> dict:
        tab = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        # parameters in the computation signature
        for line in lines[:1]:
            for om in re.finditer(r"([\w\[\],{}]+)\s+%?([\w.\-]+)(?=[,)])", line):
                pass
        return tab

    def walk(name: str) -> _Stats:
        if name in memo:
            return memo[name]
        st = _Stats()
        memo[name] = st
        lines = comps.get(name, ())
        tab = symtab(lines)

        for line in lines[1:] if lines else ():
            m = _OP_RE.match(line)
            if not m:
                continue
            _, rtype, op = m.groups()
            rest = line[m.end():]
            rbytes = _type_bytes(rtype)
            relems = sum(_nelems(s) for _, s in _dims(rtype))

            if op == "while":
                bm = _BODY_RE.search(line)
                cm = _COND_RE.search(line)
                if not bm:
                    continue
                body = walk(bm.group(1))
                cond = walk(cm.group(1)) if cm else _Stats()
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else None
                if trips is None and cm:
                    trips = _trip_count_from_cond(comps.get(cm.group(1), ()))
                if trips is None:
                    trips = 1
                    if body.flops or body.bytes or body.coll:
                        notes["unknown_trip_whiles"] += 1
                st.add(body, trips)
                st.add(cond, trips)
                continue

            if op in ("call", "conditional"):
                cm = _CALLS_RE.search(line)
                if cm:
                    st.add(walk(cm.group(1)))
                continue

            if op == "fusion":
                # flops: recurse (dots/elementwise inside); bytes: call
                # site, but an operand whose in-fusion parameter is only
                # consumed by slice/gather ops contributes the SLICE
                # bytes, not the whole buffer (loop bodies slice their
                # stacked inputs — counting full operands would multiply
                # whole-tensor reads by the trip count).
                cm = _CALLS_RE.search(line)
                fused_lines = comps.get(cm.group(1), ()) if cm else ()
                if cm:
                    st.flops += walk(cm.group(1)).flops
                operands = _OPERAND_RE.findall(rest)
                # a DUS-rooted fusion writes only the update region (the
                # buffer aliases in place); count the update bytes, not
                # the whole buffer
                st.bytes += _fusion_write_bytes(fused_lines, rbytes)
                param_cost = _param_read_costs(fused_lines)
                for i, o in enumerate(operands):
                    t = tab.get(o)
                    if not t:
                        continue
                    full = _type_bytes(t)
                    st.bytes += min(param_cost.get(i, full), full)
                continue

            # ---- collectives ----
            if op in _COLLECTIVES:
                G = _group_size(line)
                payload = float(rbytes)
                if op == "reduce-scatter":
                    operands = [tab.get(o) for o in
                                _OPERAND_RE.findall(rest)]
                    obytes = sum(_type_bytes(t) for t in operands if t)
                    payload = float(obytes or rbytes * G)
                if G > 1:
                    if op == "reduce-scatter":
                        vol = payload * (G - 1) / G
                    elif op == "all-gather":
                        vol = rbytes * (G - 1) / G
                    elif op == "all-reduce":
                        vol = 2.0 * rbytes * (G - 1) / G
                    elif op == "all-to-all":
                        vol = rbytes * (G - 1) / G
                    else:   # collective-permute
                        vol = float(rbytes)
                    st.coll[op] += vol
                st.ops.append({"op": op, "bytes": payload,
                               "type": rtype.strip(), "group": G,
                               "count": 1.0})
                st.bytes += rbytes
                continue

            # ---- flops ----
            if op == "dot":
                cm = _CONTRACT_RE.search(line)
                contract = 1
                if cm:
                    idxs = [int(i) for i in cm.group(1).split(",") if i]
                    ops = _OPERAND_RE.findall(rest)
                    # inline-typed operand (unoptimized HLO) or symtab
                    lhs_t = None
                    inline = _dims(rest.split(",")[0])
                    if inline:
                        lhs_t = rest.split(",")[0]
                    elif ops and ops[0] in tab:
                        lhs_t = tab[ops[0]]
                    if lhs_t:
                        dims = _dims(lhs_t)
                        if dims:
                            shape = dims[0][1]
                            for i in idxs:
                                if i < len(shape):
                                    contract *= shape[i]
                st.flops += 2.0 * relems * contract
            elif op == "convolution":
                ops = _OPERAND_RE.findall(rest)
                rhs_t = tab.get(ops[1]) if len(ops) > 1 else None
                if rhs_t:
                    kd = _dims(rhs_t)
                    if kd:
                        kshape = kd[0][1]
                        out_f = 1
                        dl = _DIMLBL_RE.search(line)
                        if dl and "o" in dl.group(2):
                            out_f = kshape[dl.group(2).index("o")]
                        st.flops += 2.0 * relems * _nelems(kshape) / max(out_f, 1)
            elif op in _ELEMWISE_1:
                st.flops += relems
            elif op in _ELEMWISE_6:
                st.flops += 6.0 * relems
            elif op in _REDUCE_OPS:
                st.flops += relems  # ~1 op per output elem per reduced elem is
                                    # closer, but reduces are bandwidth-bound

            # ---- bytes ----
            if op in _SLICE_OPS:
                if op in ("dynamic-update-slice", "scatter"):
                    # read+write the update region: 2x the update operand
                    # (second operand), plus nothing for the aliased rest
                    ops_ = _OPERAND_RE.findall(rest)
                    upd = tab.get(ops_[1]) if len(ops_) > 1 else None
                    st.bytes += 3 * _type_bytes(upd) if upd else rbytes
                else:
                    st.bytes += 2 * rbytes       # read slice + write result
            elif op not in _SKIP_BYTES:
                operands = [tab.get(o) for o in _OPERAND_RE.findall(rest)]
                st.bytes += rbytes + sum(_type_bytes(t) for t in operands if t)
        return st

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fallback: largest computation
        total = _Stats()
        for name in comps:
            total.add(walk(name))
    else:
        total = walk(entry)

    coll = dict(total.coll)
    coll["total"] = sum(total.coll.values())
    return {"flops": total.flops, "bytes": total.bytes,
            "collectives": coll, "collective_ops": list(total.ops),
            **notes}


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat wrapper: collective traffic only."""
    s = module_stats(hlo_text)
    out = dict(s["collectives"])
    out["unknown_trip_whiles"] = s["unknown_trip_whiles"]
    return out
