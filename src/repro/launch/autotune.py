"""Layout-autotuner CLI: plan, predict, and gate the cost model.

Static companion to ``agg_layout="auto"`` (training/step.py →
core/engine._resolve_plan → analysis.costmodel.plan_layouts): everything
here runs WITHOUT devices — the planner and the contract predictor are
pure functions of shapes, so this is safe in any CI job.

Usage:
  python -m repro.launch.autotune                # all three checks
  python -m repro.launch.autotune --plan         # plan the lint arch,
                                                 #   both meshes, every
                                                 #   aggregator; assert
                                                 #   determinism
  python -m repro.launch.autotune --predict      # BENCH_agg.json drift
                                                 #   gate + pick check
  python -m repro.launch.autotune --contracts    # exact predicted-vs-
                                                 #   extracted counts
                                                 #   over BENCH_contracts
  python -m repro.launch.autotune --factor 2.0   # drift gate (×, both
                                                 #   ways)
  python -m repro.launch.autotune --tol 0.25     # pick acceptance band

Exit code 1 on any drift/pick/contract failure.  DESIGN.md §Cost.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_AGG = REPO_ROOT / "BENCH_agg.json"
DEFAULT_CONTRACTS = REPO_ROOT / "BENCH_contracts.json"


def _load(path) -> dict | None:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    with open(path) as f:
        return json.load(f)


def run_plan(out=print) -> int:
    """Plan the lint arch's leaves for every registered aggregator on
    both lint meshes; print the plans and assert they are deterministic
    (two calls, identical picks — the trace-cache contract)."""
    from ..core.engine import registered
    from ..analysis.costmodel import _lint_leaves, plan_layouts
    from ..analysis.matrix import LINT_MESHES

    failures = 0
    for mesh_name in sorted(LINT_MESHES):
        leaves = [(v_local, "f32")
                  for _k, _n, v_local, _t in _lint_leaves(mesh_name)]
        m = dict(zip(LINT_MESHES[mesh_name][1],
                     LINT_MESHES[mesh_name][0]))["data"]
        for agg in sorted(registered()):
            p1 = plan_layouts(agg, m, leaves)
            p2 = plan_layouts(agg, m, leaves)
            if p1 != p2:
                out(f"FAIL {agg}/{mesh_name}: plan not deterministic")
                failures += 1
                continue
            out(f"{mesh_name:>4} m={m} {p1.describe()}")
    return failures


def run_predict(agg_path, factor: float, tol: float, out=print) -> int:
    from ..analysis.costmodel import validate_pick, validate_rows

    bench = _load(agg_path)
    if bench is None:
        out(f"skip: {agg_path} not found (run benchmarks/agg_cost.py)")
        return 0
    errors = validate_rows(bench, factor=factor)
    errors += validate_pick(bench, tol=tol)
    for e in errors:
        out(f"FAIL {e}")
    if not errors:
        n = len(bench.get("rows", []))
        out(f"predict: {n} measured rows within {factor:g}x of the "
            f"cost model; planner picks within {tol:.0%} of best")
    return len(errors)


def run_contracts(contracts_path, out=print) -> int:
    from ..analysis.costmodel import validate_contracts

    contracts = _load(contracts_path)
    if contracts is None:
        out(f"skip: {contracts_path} not found "
            f"(run python -m repro.launch.lint --all --record)")
        return 0
    errors = validate_contracts(contracts)
    for e in errors:
        out(f"FAIL {e}")
    if not errors:
        n = len(contracts.get("cases", []))
        out(f"contracts: {n} cases match the predicted collective "
            f"counts/bytes exactly")
    return len(errors)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.autotune",
        description="layout-autotuner planner / drift-gate CLI")
    ap.add_argument("--plan", action="store_true",
                    help="plan the lint arch on both meshes")
    ap.add_argument("--predict", action="store_true",
                    help="BENCH_agg.json drift gate + pick check")
    ap.add_argument("--contracts", action="store_true",
                    help="exact contract prediction check")
    ap.add_argument("--agg", default=str(DEFAULT_AGG),
                    help="BENCH_agg.json path")
    ap.add_argument("--budgets", default=str(DEFAULT_CONTRACTS),
                    help="BENCH_contracts.json path")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="drift gate: measured within FACTOR of "
                         "predicted, both ways (default 2.0)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="pick acceptance band vs best measured layout "
                         "(default 0.25)")
    args = ap.parse_args(argv)

    which = [args.plan, args.predict, args.contracts]
    run_all = not any(which)
    failures = 0
    if args.plan or run_all:
        failures += run_plan()
    if args.predict or run_all:
        failures += run_predict(args.agg, args.factor, args.tol)
    if args.contracts or run_all:
        failures += run_contracts(args.budgets)
    if failures:
        print(f"autotune: {failures} failure(s)")
        return 1
    print("autotune: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
