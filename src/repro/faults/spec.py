"""FaultSpec registry: declarative infrastructure faults, mirroring the
AggregatorSpec / AttackSpec idiom (core/engine.py, core/threat.py).

Byzantine attacks model an *adversary*; faults model *mundane
breakage* — crashed hosts, NaN bursts on honest workers, torn
checkpoints, frozen swap sources, wedged serve slots.  Alistarh et al.
(1803.08917) note these dominate real Byzantine behaviour, and the
elastic trainer (DESIGN.md §Elastic) + serve loop (§Serve) had no
systematic way to inject or recover from them.

Registry contract (DESIGN.md §Faults)
-------------------------------------
A :class:`FaultSpec` declares:

* ``scope`` — where the fault lands:

    ``worker``  the round's [m] active mask (host crash, flapping):
                ``inject(mask, targets) -> mask'`` is a pure rule over
                the arrival mask, applied every step the fault is
                active.  ``permanent=True`` (host crash) makes the
                trigger latch: once fired, active forever.
    ``grad``    the in-step NaN-burst mask ([m] f32 consumed by the
                guarded train step — training/step.py multiplies the
                targeted workers' loss by NaN inside the differentiated
                function, so the whole gradient of an HONEST worker
                goes non-finite, distinct from any attack):
                ``inject(fault, targets) -> fault'``.
    ``ckpt``    on-disk checkpoint state: ``inject(ckpt_dir, step, rng)
                -> str`` mutilates step ``step``'s files (truncated
                npz, manifest–npz disagreement) and returns a
                description.  Applied once per trigger firing.
    ``serve``   the serve loop: ``inject(ctx, rng) -> str`` where
                ``ctx`` is the harness's serve context (``.loop`` —
                a ServeLoop; ``.freeze(ticks)`` — the checkpoint
                publisher).  Applied once per firing.

* ``trigger`` schedules are data, not code: a :class:`Trigger` turns
  (at, every, prob, duration) into a seeded boolean activity vector,
  so a chaos run is reproducible from ``(events, seed)`` alone.

The recovery side lives in :mod:`.supervisor` (train) and in the
HotSwapper quarantine + scheduler requeue (serving/).  Adding a fault
is one :func:`register` call — it is then available to
:class:`ChaosPlan` schedules, ``benchmarks/chaos.py``, and the tests.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable, Tuple

import numpy as np

SCOPES = ("worker", "grad", "ckpt", "serve")


@dataclass(frozen=True)
class Trigger:
    """When a fault fires.  ``prob`` > 0 draws per-step Bernoulli
    firings (from step ``at`` on); otherwise the fault fires at ``at``
    and then every ``every`` steps (``every=0`` = once).  Each firing
    stays active for ``duration`` steps."""

    at: int = 0
    every: int = 0
    prob: float = 0.0
    duration: int = 1

    def __post_init__(self):
        if self.at < 0 or self.every < 0:
            raise ValueError(f"at/every must be >= 0, got at={self.at} "
                             f"every={self.every}")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")

    def schedule(self, n_steps: int, rng) -> np.ndarray:
        """[n_steps] bool activity vector (seeded via ``rng``)."""
        active = np.zeros(n_steps, bool)
        if self.prob > 0:
            fires = np.flatnonzero(rng.random(n_steps) < self.prob)
            fires = fires[fires >= self.at]
        elif self.every > 0:
            fires = np.arange(self.at, n_steps, self.every)
        else:
            fires = np.array([self.at]) if self.at < n_steps else np.array([], int)
        for f in fires:
            active[f:f + self.duration] = True
        return active


@dataclass(frozen=True)
class FaultSpec:
    name: str
    scope: str
    inject: Callable
    permanent: bool = False       # worker scope: once fired, never rejoins
    doc: str = ""

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(f"fault {self.name!r}: scope must be one of "
                             f"{SCOPES}, got {self.scope!r}")
        if self.permanent and self.scope != "worker":
            raise ValueError(f"fault {self.name!r}: permanent is only "
                             f"meaningful for worker scope")


_REGISTRY: dict = {}


def register(spec: FaultSpec) -> FaultSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> FaultSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown fault {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# shipped faults
# ---------------------------------------------------------------------------

def _drop_targets(mask: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """worker scope: targeted workers vanish from the round."""
    return mask * (1.0 - targets)


def _nan_targets(fault: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """grad scope: targeted workers' losses go NaN inside the step."""
    return np.maximum(fault, targets)


def _truncate_npz(ckpt_dir: str, step: int, rng) -> str:
    """Torn write: the npz loses its tail (manifest stays — the crash
    happened after the manifest rename, e.g. media corruption)."""
    npz = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    raw = open(npz, "rb").read()
    with open(npz, "wb") as f:
        f.write(raw[:max(1, len(raw) // 2)])
    return f"truncated {os.path.basename(npz)} to {len(raw) // 2}B"


def _drop_manifest_key(ckpt_dir: str, step: int, rng) -> str:
    """Manifest–npz disagreement: one array silently missing from the
    npz (e.g. a partial rewrite by a buggy uploader)."""
    npz = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(npz) as data:
        arrays = {k: data[k] for k in data.files}
    victim = sorted(arrays)[int(rng.integers(len(arrays)))]
    del arrays[victim]
    with open(npz, "wb") as f:
        np.savez(f, **arrays)
    return f"dropped key {victim!r} from {os.path.basename(npz)}"


def _freeze_swap(ctx, rng) -> str:
    """Swap source frozen: the publisher stops shipping new checkpoints
    for the firing's duration (set by the harness via ctx)."""
    ticks = getattr(ctx, "stale_ticks", 8)
    ctx.freeze(ticks)
    return f"froze checkpoint publishing for {ticks} ticks"


def _stall_slot(ctx, rng) -> str:
    """One busy decode slot stops making progress (wedged device /
    lost worker) until the stall expires or the request is requeued."""
    loop = ctx.loop
    busy = [s for s in range(loop.max_batch)
            if loop._req_of_slot[s] is not None]
    if not busy:
        return "no busy slot to stall"
    slot = busy[int(rng.integers(len(busy)))]
    ticks = getattr(ctx, "stall_ticks", 16)
    loop.inject_stall(slot, ticks)
    return f"stalled slot {slot} for {ticks} ticks"


register(FaultSpec("host_crash", "worker", _drop_targets, permanent=True,
                   doc="permanent drop from the elastic active mask"))
register(FaultSpec("flap", "worker", _drop_targets,
                   doc="worker drops and rejoins after `duration` steps"))
register(FaultSpec("nan_burst", "grad", _nan_targets,
                   doc="honest workers emit NaN gradients for a burst"))
register(FaultSpec("torn_ckpt", "ckpt", _truncate_npz,
                   doc="checkpoint npz truncated mid-file"))
register(FaultSpec("corrupt_ckpt", "ckpt", _drop_manifest_key,
                   doc="manifest–npz key disagreement"))
register(FaultSpec("stale_swap", "serve", _freeze_swap,
                   doc="hot-swap source frozen: no new checkpoints land"))
register(FaultSpec("slot_stall", "serve", _stall_slot,
                   doc="one serve slot stops making decode progress"))


# ---------------------------------------------------------------------------
# seeded schedules over a worker set
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: a registered spec name, its trigger, and —
    for worker/grad scopes — the targeted workers (explicit ``workers``
    tuple, or ``n`` drawn from the plan's seeded rng)."""

    fault: str
    trigger: Trigger = field(default_factory=Trigger)
    workers: Tuple[int, ...] = ()
    n: int = 1


class ChaosPlan:
    """Precomputed seeded fault schedule: (events, m, n_steps, seed) →
    per-step worker-drop masks, grad-fault masks, and firing edges for
    the one-shot scopes.  Pure data — the same plan drives the faulted
    run and is recorded verbatim into BENCH_faults.json."""

    def __init__(self, events, m: int, n_steps: int, seed: int = 0):
        self.events = list(events)
        self.m, self.n_steps, self.seed = m, n_steps, seed
        self._active = np.zeros((len(self.events), n_steps), bool)
        self._targets = np.zeros((len(self.events), m), np.float32)
        for i, ev in enumerate(self.events):
            spec = get_spec(ev.fault)
            rng = np.random.default_rng((seed, i))
            sched = ev.trigger.schedule(n_steps, rng)
            if spec.permanent and sched.any():
                sched[int(np.argmax(sched)):] = True
            self._active[i] = sched
            if spec.scope in ("worker", "grad"):
                t = np.zeros(m, np.float32)
                if ev.workers:
                    t[list(ev.workers)] = 1.0
                else:
                    t[rng.choice(m, size=min(ev.n, m), replace=False)] = 1.0
                object.__setattr__(ev, "workers",
                                   tuple(int(w) for w in np.flatnonzero(t)))
                self._targets[i] = t

    def _apply(self, scope: str, step: int, init: np.ndarray) -> np.ndarray:
        out = init
        for i, ev in enumerate(self.events):
            spec = get_spec(ev.fault)
            if spec.scope == scope and self._active[i, step]:
                out = spec.inject(out, self._targets[i])
        return out

    def worker_mask(self, step: int) -> np.ndarray:
        """[m] f32 survival mask (1 = unaffected) for this step —
        multiply into the arrival schedule's active mask."""
        return self._apply("worker", step, np.ones(self.m, np.float32))

    def grad_faults(self, step: int) -> np.ndarray:
        """[m] f32 NaN-burst mask for the guarded train step."""
        return self._apply("grad", step, np.zeros(self.m, np.float32))

    def fired(self, step: int):
        """(event, spec) pairs whose trigger EDGES on at this step —
        the one-shot scopes (ckpt, serve) inject on the edge."""
        out = []
        for i, ev in enumerate(self.events):
            if self._active[i, step] and (step == 0
                                          or not self._active[i, step - 1]):
                out.append((ev, get_spec(ev.fault)))
        return out

    def onsets(self):
        """[(event, first step)] for every event that ever fires —
        the MTTR accounting anchors (benchmarks/chaos.py)."""
        out = []
        for i, ev in enumerate(self.events):
            if self._active[i].any():
                out.append((ev, int(np.argmax(self._active[i]))))
        return out

    def describe(self) -> list:
        """JSON-able schedule record for BENCH_faults.json."""
        rows = []
        for (ev, at) in self.onsets():
            spec = get_spec(ev.fault)
            rows.append({"fault": ev.fault, "scope": spec.scope, "at": at,
                         "duration": ev.trigger.duration,
                         "workers": list(ev.workers)})
        return rows
