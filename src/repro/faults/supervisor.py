"""Self-healing supervisor for the elastic train loop (DESIGN.md
§Faults).

Detection is IN the compiled step (training/step.py with
``recovery.guard``): a non-finite gnorm/loss or a loss-spike vs the
supervisor's EMA holds the update on-device (``where(ok, new, old)``)
and a per-worker finiteness vector rides out as the ``worker_ok``
metric — one scalar psum of extra cost, zero recompiles.  Everything
here is host-side POLICY over those signals:

* quorum collapse — ``n_active < quorum`` after faults/evictions: run
  the round anyway iff the shrunk set still holds the honest-majority
  bound ``n_active > 2·floor(alpha·n_active)`` (the in-step
  ``n_byzantine`` already scales with the traced active count), else
  hold the step entirely;
* eviction / re-admission — workers with ``worker_ok == 0`` on a held
  step collect strikes and are evicted from the validity mask (a
  traced-value edit — the PR-7 elastic idiom, no recompile); evicted
  workers are re-admitted on probation after ``readmit_after`` steps;
* bounded rollback — ``rollback_after`` consecutive held steps restore
  the last_good checkpoint (checkpoint/ckpt.py pointer, advanced only
  after restore-validation) with exponential backoff between attempts
  and a hard ``max_rollbacks`` retry budget (exceeding it raises
  :class:`SupervisorError` — crash-looping forever is worse than
  stopping loudly).

The supervisor never reads the fault schedule: it sees only the step
metrics, so detection latency and eviction targeting are honest.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np

from ..checkpoint import ckpt
from ..configs.base import ByzantineConfig, RecoveryConfig


class SupervisorError(RuntimeError):
    """Recovery budget exhausted — the run cannot self-heal."""


def feasible_round(n_active: int, alpha: float) -> bool:
    """Can a shrunk round of ``n_active`` workers still be aggregated
    soundly?  The adversary holds floor(alpha·n_active) of them, so we
    need the same honest-majority bound ByzantineConfig enforces for
    the configured quorum."""
    return n_active >= 1 and n_active > 2 * math.floor(alpha * n_active)


_HELD_METRICS = ("loss", "ce", "gnorm", "n_selected", "n_selected_min")


class Supervisor:
    """Drives one guarded elastic step (training/step.py,
    ``recovery.guard=True``): ``run_step`` wraps each ``step_fn`` call
    with the recovery policy above; ``checkpoint`` saves with
    keep-last-k retention and advances ``last_good`` only after
    restore-validation passes."""

    def __init__(self, step_fn, bcfg: ByzantineConfig,
                 rcfg: RecoveryConfig, m: int,
                 ckpt_dir: Optional[str] = None, like=None, shardings=None):
        if not bcfg.elastic:
            raise ValueError("Supervisor requires an elastic config "
                             "(ByzantineConfig.quorum/max_m)")
        self.step_fn = step_fn
        self.bcfg, self.rcfg, self.m = bcfg, rcfg, m
        self.ckpt_dir, self.shardings = ckpt_dir, shardings
        # snapshot `like` to host NOW: the live param tree is donated
        # into the jitted step, and a donated buffer is deleted — a
        # template that aliases it would break every later
        # validate/restore
        self.like = (None if like is None
                     else jax.tree.map(np.asarray, like))
        self.evicted = np.zeros(m, bool)
        self.strikes = np.zeros(m, np.int64)
        self.readmit_at = np.full(m, -1, np.int64)
        self.loss_ema: Optional[float] = None
        self.rollbacks = 0
        self.holds = 0
        self.quorum_shrinks = 0
        self.quorum_holds = 0
        self.evictions = 0
        self.readmissions = 0
        self.ckpt_quarantines = 0
        self._consec_bad = 0
        self._cooldown_until = -1
        self.events: list = []      # (step, kind, detail)
        self.log: list = []         # per-step {"step", "ok", "n_active"}

    # -- helpers -------------------------------------------------------
    def _event(self, step: int, kind: str, detail: str = "") -> None:
        self.events.append({"step": int(step), "kind": kind,
                            "detail": detail})

    def _held_metrics(self, n_active: int, reason: str) -> dict:
        met = {k: float("nan") for k in _HELD_METRICS}
        met.update(n_active=float(n_active), step_ok=0.0, grad_finite=1.0,
                   loss_spike=0.0, held=reason)
        return met

    def active_mask(self, step: int, sched_active=None) -> np.ndarray:
        """This round's [m] validity mask: the arrival schedule minus
        evicted workers, with probation re-admission applied first."""
        back = self.evicted & (self.readmit_at >= 0) \
            & (self.readmit_at <= step)
        for w in np.flatnonzero(back):
            self.evicted[w] = False
            self.strikes[w] = 0
            self.readmit_at[w] = -1
            self.readmissions += 1
            self._event(step, "readmit", f"worker {w}")
        act = (np.ones(self.m, np.float32) if sched_active is None
               else np.asarray(sched_active, np.float32).copy())
        act[self.evicted] = 0.0
        return act

    # -- the supervised step -------------------------------------------
    def run_step(self, params, opt_state, batch, step: int, key,
                 sched_active=None, faults=None):
        """One supervised round.  Returns (params, opt_state, metrics)
        where metrics are host floats (plus ``held`` on skipped
        rounds).  ``faults`` is the [m] grad-fault mask a chaos harness
        injects; the supervisor forwards it blindly — detection runs on
        the step's own metrics."""
        import jax
        import jax.numpy as jnp

        rcfg = self.rcfg
        act = self.active_mask(step, sched_active)
        n_active = int(act.sum())
        quorum = self.bcfg.quorum or self.m

        if n_active < quorum:
            if not feasible_round(n_active, self.bcfg.alpha):
                self.quorum_holds += 1
                self._event(step, "quorum_hold",
                            f"n_active={n_active} < quorum={quorum} and "
                            f"the honest-majority bound fails — holding")
                met = self._held_metrics(n_active, "quorum")
                self.log.append({"step": step, "ok": False,
                                 "n_active": n_active})
                return params, opt_state, met
            self.quorum_shrinks += 1
            self._event(step, "quorum_shrink",
                        f"running {n_active} < quorum={quorum} "
                        f"(bound holds at alpha={self.bcfg.alpha})")

        flt = (np.zeros(self.m, np.float32) if faults is None
               else np.asarray(faults, np.float32))
        ema = np.float32(-1.0 if self.loss_ema is None else self.loss_ema)
        new_params, new_opt, met = self.step_fn(
            params, opt_state, batch, jnp.int32(step), key,
            jnp.asarray(act), jnp.asarray(flt), ema)
        met = {k: np.asarray(v) for k, v in met.items()}
        worker_ok = met.pop("worker_ok", np.ones(self.m, np.float32))
        ok = bool(met["step_ok"] > 0)
        met = {k: float(v) for k, v in met.items()}
        self.log.append({"step": step, "ok": ok, "n_active": n_active})

        if ok:
            self._consec_bad = 0
            d = rcfg.ema_decay
            loss = met["loss"]
            self.loss_ema = (loss if self.loss_ema is None
                             else d * self.loss_ema + (1 - d) * loss)
            return new_params, new_opt, met

        # held on-device: new_params IS params (where-passthrough)
        self.holds += 1
        self._consec_bad += 1
        reason = ("spike" if met.get("loss_spike") else "nonfinite")
        self._event(step, "hold", f"step held ({reason}): "
                    f"gnorm={met['gnorm']} loss={met['loss']}")
        bad = np.flatnonzero((np.asarray(worker_ok) == 0) & (act > 0))
        for w in bad:
            self.strikes[w] += 1
            if not self.evicted[w] and self.strikes[w] >= rcfg.evict_after:
                self.evicted[w] = True
                self.readmit_at[w] = step + rcfg.readmit_after
                self.evictions += 1
                self._event(step, "evict",
                            f"worker {w} (worker_ok=0, "
                            f"strike {int(self.strikes[w])})")
        if (self._consec_bad >= rcfg.rollback_after
                and self.ckpt_dir is not None
                and step >= self._cooldown_until):
            new_params = self._rollback(step, new_params)
        met["held"] = reason
        return new_params, new_opt, met

    def _rollback(self, step: int, params):
        """Restore the newest restorable checkpoint, last_good first.
        Exponential backoff between attempts; a hard retry budget."""
        candidates = []
        lg = ckpt.last_good_step(self.ckpt_dir)
        if lg is not None:
            candidates.append(lg)
        candidates += [s for s in reversed(ckpt.steps(self.ckpt_dir))
                       if s != lg]
        for cand in candidates:
            try:
                tree, got = ckpt.restore(self.ckpt_dir, self.like,
                                         step=cand,
                                         shardings=self.shardings)
            except Exception as e:            # quarantine and try older
                self._event(step, "rollback_skip",
                            f"step {cand} unrestorable: "
                            f"{type(e).__name__}")
                continue
            self.rollbacks += 1
            if self.rollbacks > self.rcfg.max_rollbacks:
                raise SupervisorError(
                    f"rollback budget exhausted ({self.rcfg.max_rollbacks})"
                    f" — still unhealthy at step {step}")
            self._cooldown_until = step + (self.rcfg.backoff_base
                                           * 2 ** (self.rollbacks - 1))
            self._consec_bad = 0
            self.loss_ema = None              # re-learn the baseline
            self._event(step, "rollback",
                        f"restored step {got} (rollback "
                        f"{self.rollbacks}/{self.rcfg.max_rollbacks}, "
                        f"cooldown until {self._cooldown_until})")
            return tree
        self._event(step, "rollback_failed", "no restorable checkpoint")
        return params

    # -- checkpointing with a validated last_good pointer --------------
    def checkpoint(self, params, step: int) -> bool:
        """keep-last-k save; ``last_good`` advances only if the written
        checkpoint passes restore-validation (torn/corrupt saves are
        quarantined, never pointed at)."""
        assert self.ckpt_dir is not None
        ckpt.save(self.ckpt_dir, params, step=step,
                  keep=self.rcfg.keep_ckpts)
        try:
            ckpt.mark_good(self.ckpt_dir, step, like=self.like)
        except Exception as e:
            self.ckpt_quarantines += 1
            self._event(step, "ckpt_quarantine",
                        f"step {step} failed validation: "
                        f"{type(e).__name__}")
            return False
        return True

    def summary(self) -> dict:
        return {"holds": self.holds, "rollbacks": self.rollbacks,
                "evictions": self.evictions,
                "readmissions": self.readmissions,
                "quorum_shrinks": self.quorum_shrinks,
                "quorum_holds": self.quorum_holds,
                "ckpt_quarantines": self.ckpt_quarantines,
                "events": self.events}
