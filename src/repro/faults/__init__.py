"""Fault-injection registry + self-healing supervision (DESIGN.md
§Faults).  Mirrors the AggregatorSpec/AttackSpec idiom: declarative
FaultSpecs with seeded Trigger schedules, a ChaosPlan that compiles
them into per-step masks, and a Supervisor implementing detection →
hold → evict → rollback over the elastic train loop."""
from .spec import (SCOPES, ChaosPlan, FaultEvent, FaultSpec, Trigger,
                   get_spec, register, registered)
from .supervisor import Supervisor, SupervisorError, feasible_round

__all__ = ["SCOPES", "ChaosPlan", "FaultEvent", "FaultSpec", "Trigger",
           "get_spec", "register", "registered",
           "Supervisor", "SupervisorError", "feasible_round"]
