"""Config dataclasses for the model zoo and the training system.

Every assigned architecture is expressed as a ``ModelConfig``; the
reduced smoke variants are derived with ``reduced()``.  All fields are
plain data so configs hash/compare and never touch jax at import time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionSpec:
    """Attention family description.

    kind:
      - "gqa": grouped-query attention (n_kv_heads <= n_heads)
      - "mla": multi-head latent attention (DeepSeek-V2 / MiniCPM3)
      - "none": attention-free layer stack (rwkv6)
    """

    kind: str = "gqa"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # sliding window (tokens); 0 = full attention.  The long_500k shape
    # auto-selects window attention for full-attention archs.
    window: int = 0
    # --- MLA-only fields ---
    q_lora_rank: int = 0          # 0 = full-rank q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0


@dataclass(frozen=True)
class MoESpec:
    n_experts: int = 0            # 0 = dense FFN
    top_k: int = 1
    n_shared: int = 0             # shared (always-on) experts
    d_ff_expert: int = 0          # per-expert hidden size
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class SSMSpec:
    """Mamba2 (SSD) block spec."""

    state_dim: int = 64
    head_dim: int = 64
    n_heads: int = 0              # derived: d_inner // head_dim if 0
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class RWKVSpec:
    """RWKV6 ("Finch") block spec — data-dependent decay WKV."""

    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    # 0 = paper-baseline per-token scan; Q > 0 = chunked-parallel WKV
    # (flash-linear-attention form, §Perf) with Q-token chunks.
    chunk: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attention: AttentionSpec
    activation: str = "silu"      # silu | gelu | relu2 (squared relu)
    moe: MoESpec = field(default_factory=MoESpec)
    ssm: Optional[SSMSpec] = None
    rwkv: Optional[RWKVSpec] = None
    # hybrid layout: every ``hybrid_attn_every`` ssm layers, apply the
    # single SHARED attention block (zamba2 style).  0 = not hybrid.
    hybrid_attn_every: int = 0
    # moe layout: first ``n_dense_layers`` layers use the dense FFN
    # (deepseek-v2 uses 1 dense layer before the MoE stack).
    n_dense_layers: int = 0
    # modality frontend: "none" | "vision" | "audio".  Frontends are
    # stubs — input_specs() provides precomputed patch/frame embeddings.
    frontend: str = "none"
    n_prefix_tokens: int = 0      # patch/frame embedding count for vlm/audio
    tie_embeddings: bool = False
    rms_eps: float = 1e-5
    dtype: str = "bfloat16"       # activation/param dtype
    source: str = ""              # citation

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def d_head_total(self) -> int:
        return self.attention.n_heads * self.attention.head_dim

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (<=512 d_model,
        2 layers, <=4 experts)."""
        att = self.attention
        d_model = min(self.d_model, 256)
        n_heads = min(att.n_heads, 4)
        n_kv = min(att.n_kv_heads, max(1, n_heads // 2)) if att.kind != "none" else 0
        head_dim = min(att.head_dim, 64)
        red_att = replace(
            att,
            n_heads=n_heads,
            n_kv_heads=max(1, n_kv),
            head_dim=head_dim,
            q_lora_rank=min(att.q_lora_rank, 64) if att.q_lora_rank else 0,
            kv_lora_rank=min(att.kv_lora_rank, 32) if att.kv_lora_rank else 0,
            qk_nope_dim=min(att.qk_nope_dim, 32) if att.qk_nope_dim else 0,
            qk_rope_dim=min(att.qk_rope_dim, 16) if att.qk_rope_dim else 0,
            v_head_dim=min(att.v_head_dim, 32) if att.v_head_dim else 0,
            window=min(att.window, 64) if att.window else 0,
        )
        moe = self.moe
        if self.is_moe:
            moe = replace(
                moe,
                n_experts=min(moe.n_experts, 4),
                top_k=min(moe.top_k, 2),
                n_shared=min(moe.n_shared, 1),
                d_ff_expert=min(moe.d_ff_expert, 128),
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, state_dim=min(self.ssm.state_dim, 16),
                          head_dim=32, chunk=32)
        rwkv = None
        if self.rwkv is not None:
            rwkv = replace(self.rwkv, head_dim=32, decay_lora=16, mix_lora=8)
        n_layers = 2 if self.hybrid_attn_every == 0 else 4
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            attention=red_att,
            moe=moe,
            ssm=ssm,
            rwkv=rwkv,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            dtype="float32",
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"


@dataclass(frozen=True)
class ByzantineConfig:
    """Robust-aggregation config — the paper's technique knobs."""

    # any rule registered in core.engine: brsgd | mean | median |
    # trimmed_mean | krum | multi_krum | geomedian — all of them run in
    # both scopes (global and blocked) and both layouts.
    aggregator: str = "brsgd"
    beta: float = 0.5             # kept fraction (paper: beta = 1/2)
    threshold: float = 0.0        # 𝔗; 0.0 = auto (lower quartile of l1)
    trim_frac: float = 0.1        # trimmed_mean only
    krum_f: int = 0               # assumed byzantine count for krum; 0=auto
    # ------------------------------------------------------------------
    # threat model (training-time fault injection for experiments).
    # attack: "none" or any spec registered in core.threat — the shipped
    # registry (threat.registered()) is gaussian | negation | scale |
    # sign_flip | alie | ipm (gradient scope) plus label_flip (data
    # scope); every entry runs in all three scopes (dense simulation,
    # shard_map global, blocked).  launch/train.py validates --attack
    # against the live registry, never against this comment.
    attack: str = "none"
    alpha: float = 0.0            # fraction of byzantine workers
    # membership policy — WHICH ⌊αm⌋ workers are byzantine:
    #   "prefix"   workers 0..⌊αm⌋-1 (the paper's arbitrary-identity set)
    #   "random"   fixed random subset drawn once from byz_seed
    #   "resample" fresh subset every step (drawn from the step key)
    membership: str = "prefix"
    byz_seed: int = 0             # membership="random" draw seed
    # per-attack knobs.  (The former `attack_scale` was overloaded: one
    # field served as scale's multiplier, negation's c, and — via a
    # magic `< 100` heuristic — ALIE's z and IPM's ε.  Retired.)
    gaussian_std: float = 200.0   # gaussian: noise std (paper: 200)
    scale_factor: float = 1e10    # scale: multiplier on own gradient
    negation_factor: float = 1e10  # negation: c in -c * Σ honest
    alie_z: float = 1.5           # alie: z std-devs from honest mean
    ipm_eps: float = 0.5          # ipm: ε in -ε * mean(honest)
    # ------------------------------------------------------------------
    # elastic worker set (quorum aggregation).  0/0 = the classic fixed-m
    # bulk-synchronous round over every worker.  max_m is the padded
    # worker-slot count (the mesh's worker extent in distributed scopes);
    # quorum is the arrival count selection fires at — workers that
    # haven't reported by then are dropped from the round via the
    # validity mask, with truthful n_selected accounting.
    max_m: int = 0
    quorum: int = 0

    def __post_init__(self):
        if self.max_m < 0 or self.quorum < 0:
            raise ValueError(
                f"max_m/quorum must be >= 0, got max_m={self.max_m} "
                f"quorum={self.quorum}")
        if self.max_m and self.quorum > self.max_m:
            raise ValueError(
                f"quorum={self.quorum} exceeds max_m={self.max_m} worker "
                f"slots")
        if self.quorum:
            # the adversary controls floor(alpha * n_active) of whichever
            # workers make the round, so the smallest round the config
            # permits must still hold an honest majority
            n_byz = int(self.alpha * self.quorum)
            if self.quorum <= 2 * n_byz:
                raise ValueError(
                    f"quorum={self.quorum} violates the honest-majority "
                    f"bound quorum > 2*n_byzantine: with alpha="
                    f"{self.alpha}, a {self.quorum}-worker round has "
                    f"n_byzantine = floor(alpha*quorum) = {n_byz} and "
                    f"2*{n_byz} >= {self.quorum} — robust selection over "
                    f"a possibly-byzantine-majority quorum is unsound; "
                    f"raise quorum or lower alpha")

    @property
    def elastic(self) -> bool:
        """True when this config opts into the elastic worker set
        (pad-to-max-m + validity mask + quorum select)."""
        return bool(self.max_m or self.quorum)


@dataclass(frozen=True)
class RecoveryConfig:
    """Fault-detection and self-healing knobs (DESIGN.md §Faults).

    ``guard`` compiles the finite-gradient / loss-spike guard INTO the
    jitted train step: a non-finite gnorm/loss or a loss above
    ``spike_mult``× the supervisor's EMA holds the update (params and
    optimizer state pass through unchanged via ``where``), and a
    per-worker finiteness vector (``worker_ok``) rides out as a metric
    so the supervisor can evict the implicated workers from the traced
    validity mask — zero recompiles, one extra scalar psum.  The guard
    requires the elastic worker set (``ByzantineConfig.quorum/max_m``):
    eviction is a validity-mask edit.  Everything else here is
    host-side supervisor policy (faults/supervisor.py)."""

    guard: bool = False
    spike_mult: float = 10.0      # hold when loss > spike_mult * EMA
    ema_decay: float = 0.9        # loss EMA decay (host-side)
    evict_after: int = 1          # worker_ok strikes before eviction
    readmit_after: int = 8        # probation steps before re-admission
    rollback_after: int = 2       # consecutive held steps before rollback
    max_rollbacks: int = 3        # retry budget; exceeding it raises
    backoff_base: int = 2         # cooldown = base * 2^(rollbacks-1) steps
    keep_ckpts: int = 3           # keep-last-k retention (checkpoint/ckpt)

    def __post_init__(self):
        if self.spike_mult <= 1.0:
            raise ValueError(f"spike_mult must be > 1, got {self.spike_mult}")
        if not 0.0 < self.ema_decay < 1.0:
            raise ValueError(f"ema_decay must be in (0, 1), got "
                             f"{self.ema_decay}")
        for k in ("evict_after", "readmit_after", "rollback_after",
                  "backoff_base", "keep_ckpts"):
            if getattr(self, k) < 1:
                raise ValueError(f"{k} must be >= 1, got {getattr(self, k)}")
        if self.max_rollbacks < 0:
            raise ValueError(f"max_rollbacks must be >= 0, got "
                             f"{self.max_rollbacks}")


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    byzantine: ByzantineConfig = field(default_factory=ByzantineConfig)
    optimizer: str = "adamw"      # sgd | momentum | adamw
    lr: float = 3e-4
    momentum: float = 0.9
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    seed: int = 0
    microbatch: int = 0           # 0 = no grad accumulation
    remat: str = "none"           # none | block  (activation checkpointing)
    # fault detection / self-healing (DESIGN.md §Faults): recovery.guard
    # compiles the finite-gradient + loss-spike hold into the step
    recovery: RecoveryConfig = field(default_factory=RecoveryConfig)
    # robust-aggregation execution strategy (DESIGN.md §2):
    #   scope  "global"  — paper-faithful: full per-worker gradient matrix
    #                      materialized, one global C1∩C2 selection.
    #          "blocked" — streaming: aggregation runs inside the backward
    #                      scan per layer-bucket (custom-VJP barrier) with
    #                      per-bucket selections; params are FSDP-sharded
    #                      over the worker axes.  Required for >20B archs.
    #                      Any registered aggregator runs here (engine
    #                      registry dispatch, see core/blocked.py).
    #          "auto"    — blocked iff param count > 20e9.
    agg_scope: str = "auto"
    #   layout "gather"  — master-collects-G baseline (all_gather over
    #                      workers, m x transient memory).
    #          "a2a"     — all_to_all re-shard: workers x dims transpose,
    #                      1x memory, stats local per dim shard.
    #          "auto"    — a2a iff param count > 5e9 (or scope blocked).
    agg_layout: str = "auto"


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
