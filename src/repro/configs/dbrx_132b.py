"""dbrx-132b [moe] — 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base]"""
from .base import AttentionSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    d_ff=10_752,
    vocab=100_352,
    attention=AttentionSpec(
        kind="gqa", n_heads=48, n_kv_heads=8, head_dim=128,
        rope_theta=500_000.0,
    ),
    activation="silu",
    moe=MoESpec(n_experts=16, top_k=4, n_shared=0, d_ff_expert=10_752),
    source="hf:databricks/dbrx-base",
)
