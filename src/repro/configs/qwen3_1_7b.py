"""qwen3-1.7b [dense] — qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B]"""
from .base import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    n_layers=28,
    d_model=2048,
    d_ff=6144,
    vocab=151_936,
    attention=AttentionSpec(
        kind="gqa", n_heads=16, n_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0,
    ),
    activation="silu",
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
)
