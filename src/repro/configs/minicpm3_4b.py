"""minicpm3-4b [dense] — MLA attention.  [hf:openbmb/MiniCPM3-4B]"""
from .base import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab=73_448,
    attention=AttentionSpec(
        kind="mla",
        n_heads=40,
        n_kv_heads=40,
        head_dim=96,            # qk_nope + qk_rope
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        rope_theta=10_000.0,
    ),
    activation="silu",
    source="hf:openbmb/MiniCPM3-4B",
)
