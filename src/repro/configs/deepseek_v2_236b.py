"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 160-expert top-6 MoE with
2 shared experts.  [arXiv:2405.04434]

Assignment line: 60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400,
MoE 160e top-6.  The assigned d_ff=1536 is the *per-expert* hidden size
(DeepSeek-V2 moe_intermediate_size); the single leading dense layer uses
the model-card intermediate_size of 12288.
"""
from .base import AttentionSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    d_ff=12288,                 # dense FFN width (layer 0)
    vocab=102_400,
    attention=AttentionSpec(
        kind="mla",
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,           # qk_nope + qk_rope
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        rope_theta=10_000.0,
    ),
    activation="silu",
    moe=MoESpec(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    n_dense_layers=1,
    source="arXiv:2405.04434",
)
