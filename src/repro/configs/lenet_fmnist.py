"""Paper-repro config: LeNet on (synthetic) FashionMNIST with m=20 workers.

Matches the paper's experimental setup (Section 5): m=20 workers, LeNet
[LeCun et al., 1998], mini-batch SGD with eta=0.03, beta=1/2, four
attacks at alpha in {0, 10%, 25%, 50%}.  The container is offline so the
data pipeline generates a FashionMNIST-like synthetic dataset
(class-conditional Gaussian blobs over 28x28 images, 10 classes).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class LeNetConfig:
    name: str = "lenet-fmnist"
    image_size: int = 28
    n_classes: int = 10
    conv_channels: tuple = (6, 16)
    fc_dims: tuple = (120, 84)
    n_workers: int = 20
    batch_per_worker: int = 32
    lr: float = 0.03
    beta: float = 0.5


CONFIG = LeNetConfig()
