"""Assigned input shapes (public pool) + shape registry."""
from __future__ import annotations

from .base import InputShape

TRAIN_4K = InputShape("train_4k", seq_len=4_096, global_batch=256, mode="train")
PREFILL_32K = InputShape("prefill_32k", seq_len=32_768, global_batch=32, mode="prefill")
DECODE_32K = InputShape("decode_32k", seq_len=32_768, global_batch=128, mode="decode")
LONG_500K = InputShape("long_500k", seq_len=524_288, global_batch=1, mode="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]
