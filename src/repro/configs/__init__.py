"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import (AttentionSpec, ByzantineConfig, InputShape, ModelConfig,
                   MoESpec, RecoveryConfig, RWKVSpec, SSMSpec, TrainConfig)
from .shapes import SHAPES, get_shape

from . import (dbrx_132b, deepseek_v2_236b, minicpm3_4b, musicgen_large,
               nemotron_4_15b, phi_3_vision_4_2b, qwen3_0_6b, qwen3_1_7b,
               rwkv6_7b, zamba2_2_7b)

ARCHS = {
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "phi-3-vision-4.2b": phi_3_vision_4_2b.CONFIG,
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "minicpm3-4b": minicpm3_4b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "zamba2-2.7b": zamba2_2_7b.CONFIG,
    "qwen3-0.6b": qwen3_0_6b.CONFIG,
    "qwen3-1.7b": qwen3_1_7b.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS", "get_config", "get_shape", "SHAPES",
    "AttentionSpec", "ByzantineConfig", "InputShape", "ModelConfig",
    "MoESpec", "RWKVSpec", "SSMSpec", "TrainConfig",
]
