"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU MLP.  [arXiv:2402.16819]"""
from .base import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    n_layers=32,
    d_model=6144,
    d_ff=24_576,
    vocab=256_000,
    attention=AttentionSpec(
        kind="gqa", n_heads=48, n_kv_heads=8, head_dim=128,
        rope_theta=10_000.0,
    ),
    activation="relu2",          # squared ReLU
    source="arXiv:2402.16819",
)
