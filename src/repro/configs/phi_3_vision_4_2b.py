"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP vision frontend.
[hf:microsoft/Phi-3-vision-128k-instruct]

The CLIP ViT-L/14-336 encoder + projector is a STUB per the build rules:
``input_specs()`` provides precomputed patch embeddings (576 patches,
already projected to d_model) that are prepended to the token stream.
"""
from .base import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab=32_064,
    attention=AttentionSpec(
        kind="gqa", n_heads=32, n_kv_heads=32, head_dim=96,
        rope_theta=10_000.0,
    ),
    activation="silu",
    frontend="vision",
    n_prefix_tokens=576,        # ViT-L/14 @ 336px -> 24x24 patches
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
