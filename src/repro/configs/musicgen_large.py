"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

The EnCodec tokenizer / conditioning encoder is a STUB: ``input_specs()``
provides the discrete audio-token stream plus precomputed conditioning
frame embeddings (prepended, 64 frames) of the right shape.
"""
from .base import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab=2048,                 # EnCodec codebook size
    attention=AttentionSpec(
        kind="gqa", n_heads=32, n_kv_heads=32, head_dim=64,
        rope_theta=10_000.0,
    ),
    activation="gelu",
    frontend="audio",
    n_prefix_tokens=64,         # conditioning frame embeddings (stub)
    source="arXiv:2306.05284",
)
