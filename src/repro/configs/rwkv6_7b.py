"""rwkv6-7b [ssm] — "Finch", attention-free, data-dependent decay WKV.
[arXiv:2404.05892]"""
from .base import AttentionSpec, ModelConfig, RWKVSpec

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    n_layers=32,
    d_model=4096,
    d_ff=14_336,
    vocab=65_536,
    attention=AttentionSpec(kind="none", n_heads=64, n_kv_heads=64, head_dim=64),
    activation="relu2",          # rwkv channel-mix uses squared relu
    # chunk=64: chunked-parallel WKV (§Perf iteration 1/2 — 12.6x lower
    # roofline bound on train_4k vs the per-token scan; chunk=0 restores
    # the paper-baseline recurrence, see EXPERIMENTS.md)
    rwkv=RWKVSpec(head_dim=64, decay_lora=64, mix_lora=32, chunk=64),
    source="arXiv:2404.05892",
)
