"""zamba2-2.7b [hybrid] — Mamba2 backbone + one SHARED attention block
applied every 6 SSM layers.  [arXiv:2411.15242]"""
from .base import AttentionSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    d_ff=10_240,                # shared-block MLP width
    vocab=32_000,
    attention=AttentionSpec(
        kind="gqa", n_heads=32, n_kv_heads=32, head_dim=80,
        rope_theta=10_000.0,
    ),
    activation="gelu",
    ssm=SSMSpec(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
