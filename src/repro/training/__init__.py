from .step import StepBundle, build_train_step, resolve_strategy
