"""Distributed train-step builder.

One ``jax.shard_map`` (partial-manual over the worker axes, 'model'
stays auto) wraps gradient computation, Byzantine attack injection,
robust aggregation, and the optimizer update:

  global scope  : per-worker full-gradient pytree -> robust_aggregate
                  (any aggregator registered in core.engine; gather or
                  a2a collective layout)
  blocked scope : FSDP params + aggregation inside the backward scan
                  (core.blocked) — the >20B path.  Any registered
                  aggregator runs per-bucket; each bucket's real
                  n_selected rides out of the backward on a selection
                  token's cotangent (a histogram over counts), so the
                  n_selected / n_selected_min metrics are truthful —
                  the seed hard-coded n_selected == m here.

The builder returns the jitted step plus the sharding trees needed by
both the real driver and the dry-run (which feeds ShapeDtypeStructs).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ByzantineConfig, ModelConfig, TrainConfig
from ..core import threat
from ..core.blocked import key_carrier, make_fsdp_agg_barrier, selection_token
from ..core.distributed import robust_aggregate
from ..launch.mesh import n_workers, worker_axes
from ..models import params as PM
from ..models import transformer as TF
from ..optim import get_optimizer

GIANT_PARAMS = 20e9
# §Perf: the a2a (workers×dims re-shard) layout beat the paper-faithful
# gather at every size measured (EXPERIMENTS.md §Perf pair 2) — auto
# now always picks it; agg_layout="gather" restores the paper baseline.
A2A_PARAMS = 0.0


def resolve_strategy(tcfg: TrainConfig) -> tuple[str, str]:
    """(scope, layout) with 'auto' resolved by model size."""
    n = PM.count_params(TF.param_defs(tcfg.model))
    scope = tcfg.agg_scope
    if scope == "auto":
        scope = "blocked" if n > GIANT_PARAMS else "global"
    layout = tcfg.agg_layout
    if layout == "auto":
        layout = "a2a" if (scope == "blocked" or n >= A2A_PARAMS) else "gather"
    return scope, layout


class StepBundle(NamedTuple):
    step_fn: object             # jitted (params, opt, batch, step, key) -> ...
    param_specs: object         # PartitionSpec pytree
    opt_specs: object
    batch_specs: dict
    scope: str
    layout: str

    def shardings(self, mesh):
        to_sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        return to_sh(self.param_specs), to_sh(self.opt_specs), to_sh(self.batch_specs)


def _opt_state_specs(opt_name: str, pspecs):
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return pspecs
    if opt_name == "adamw":
        return {"m": pspecs, "v": pspecs}
    raise ValueError(opt_name)


def _layer_slice_specs(specs):
    """Drop the leading stack-dim entry of every leaf spec (the scan
    consumes it)."""
    return jax.tree.map(lambda s: P(*s[1:]) if len(s) else s, specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs_for(cfg: ModelConfig, waxes) -> dict:
    w = tuple(waxes) if len(waxes) > 1 else waxes[0]
    out = {"tokens": P(w)}
    if cfg.n_prefix_tokens:
        out["prefix_embed"] = P(w)
    return out


def build_train_step(tcfg: TrainConfig, mesh) -> StepBundle:
    cfg = tcfg.model
    bcfg = tcfg.byzantine
    opt = get_optimizer(tcfg)
    scope, layout = resolve_strategy(tcfg)
    waxes = worker_axes(mesh)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    m = n_workers(mesh)
    defs = TF.param_defs(cfg)
    fsdp = scope == "blocked"
    pspecs = PM.pspec_tree(defs, mesh, fsdp=fsdp)
    ospecs = _opt_state_specs(tcfg.optimizer, pspecs)
    bspecs = batch_specs_for(cfg, waxes)
    remat = tcfg.remat == "block"

    # manual in_specs: params replicated over worker axes in global scope,
    # FSDP-sharded (their own pspec entries reference worker axes) in
    # blocked scope.  Under partial-manual shard_map the in_specs may only
    # mention MANUAL axes — the 'model' sharding rides along automatically.
    def manual_only(spec: P) -> P:
        return P(*[e if (e == wspec or (isinstance(e, tuple) and
                                        set(e) <= set(waxes))
                         or e in waxes) else None
                   for e in spec])

    p_in = jax.tree.map(manual_only, pspecs, is_leaf=lambda x: isinstance(x, P))
    o_in = jax.tree.map(manual_only, ospecs, is_leaf=lambda x: isinstance(x, P))
    metric_spec = P()

    @partial(shard_map, mesh=mesh,
             in_specs=(p_in, o_in, bspecs, P(), P()),
             out_specs=(p_in, o_in, {"loss": metric_spec, "ce": metric_spec,
                                     "gnorm": metric_spec,
                                     "n_selected": metric_spec,
                                     "n_selected_min": metric_spec}),
             axis_names=set(waxes), check_vma=False)
    def step(params, opt_state, batch, step_idx, key):
        # local worker batch: squeeze the sharded worker axis
        lbatch = {k: v.reshape(v.shape[1:]) if v.shape[0] == 1 else v[0]
                  for k, v in batch.items()}

        if scope == "blocked":
            lspecs = {k: _layer_slice_specs(v) for k, v in pspecs.items()
                      if k.startswith("seg_")}
            top_specs = {k: v for k, v in pspecs.items()
                         if not k.startswith("seg_")}
            # every barrier receives the RAW step key (key_carrier);
            # the bucket name (static, folded inside the barrier bwd)
            # and the scan index decorrelate the injected noise across
            # buckets and layers, while byzantine membership is drawn
            # from the unfolded key so all buckets corrupt ONE worker
            # set (threat.membership_mask, incl. the resample policy)
            barriers = {k: make_fsdp_agg_barrier(v, bcfg, waxes, k)
                        for k, v in lspecs.items()}
            top_barrier = make_fsdp_agg_barrier(top_specs, bcfg, waxes, "top")
            keyf = key_carrier(key)
            toks = {k: selection_token(m) for k in (*barriers, "top")}

            def lfn(params, toks):
                hooks = {k: (lambda p, i, b=b, t=toks[k]: b(p, t, i, keyf))
                         for k, b in barriers.items()}
                return TF.loss_fn(cfg, params, lbatch, remat=remat,
                                  seg_hooks=hooks,
                                  top_hook=lambda p: top_barrier(
                                      p, toks["top"], jnp.float32(0),
                                      keyf))

            (loss, met), (grads, tgrads) = jax.value_and_grad(
                lfn, argnums=(0, 1), has_aux=True)(params, toks)
            agg, st = grads, None    # already aggregated in backward
            # each token's cotangent is one_hot(n_selected) per barrier
            # call; gradient accumulation sums them over buckets and
            # scan iterations into one histogram over counts 0..m
            sel_hist = sum(jax.tree.leaves(tgrads))
        else:
            def lfn(params):
                return TF.loss_fn(cfg, params, lbatch, remat=remat)

            (loss, met), grads = jax.value_and_grad(lfn, has_aux=True)(params)
            grads = threat.inject(grads, key, bcfg, waxes)
            # worker-only mesh => no leaf dim can be model-sharded, so
            # gather-layout column rules may flatten N-D leaves to the
            # Pallas-eligible [m, cols] view
            flat_ok = set(mesh.axis_names) == set(waxes)
            agg, st = robust_aggregate(grads, bcfg, waxes, layout=layout,
                                       flatten_columns=flat_ok)
            sel_hist = None

        new_params, new_opt = opt.update(agg, opt_state, params, step_idx)
        if scope == "blocked":
            # fsdp-sharded leaves need a cross-worker psum; replicated
            # leaves are already global.
            from ..core.blocked import _fsdp_dim
            ss_f = jnp.float32(0)
            ss_r = jnp.float32(0)
            for g, s in zip(jax.tree.leaves(agg),
                            jax.tree.leaves(pspecs,
                                            is_leaf=lambda x: isinstance(x, P))):
                ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
                if _fsdp_dim(s, waxes) is not None:
                    ss_f += ss
                else:
                    ss_r += ss
            gnorm = jnp.sqrt(jax.lax.psum(ss_f, waxes) + ss_r)
        else:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(agg)))
        if sel_hist is not None:
            # stats were psum'd before the (replicated) selection, so
            # the histogram is identical on every worker — no further
            # cross-worker reduction needed
            counts = jnp.arange(m + 1, dtype=jnp.float32)
            n_sel = (jnp.sum(counts * sel_hist)
                     / jnp.maximum(jnp.sum(sel_hist), 1.0))
            n_sel_min = jnp.argmax(sel_hist > 0).astype(jnp.float32)
        else:
            n_sel = (jnp.sum(st.selected.astype(jnp.float32))
                     if st is not None else jnp.float32(m))
            n_sel_min = n_sel
        metrics = {
            "loss": jax.lax.pmean(loss, waxes),
            "ce": jax.lax.pmean(met["ce"], waxes),
            "gnorm": gnorm,
            "n_selected": n_sel,
            "n_selected_min": n_sel_min,
        }
        return new_params, new_opt, metrics

    return StepBundle(jax.jit(step, donate_argnums=(0, 1)),
                      pspecs, ospecs, bspecs, scope, layout)
