"""Distributed train-step builder.

Mesh execution strategy (DESIGN.md §Mesh): XLA's partial-manual
subgroups only support reduce-type collectives — worker all_gather /
all_to_all / axis_index (and any lax.scan) must live in a FULL-manual
region with no auto axis — so every region's manual axes are explicit
per scope:

  global scope  : auto-SPMD loss + ONE full-manual aggregation region.
                  The loss is a vmap over the batch's worker axis under
                  plain jit (NO shard_map): GSPMD shards the vmapped
                  compute over the worker axes and the tensor-parallel
                  math over 'model', like the serving paths.  The
                  per-worker gradient stack then enters a shard_map
                  that is manual over EVERY mesh axis — attack
                  injection + robust aggregation run there, with
                  model-sharded leaves as local shards
                  (engine.aggregate_sharded model_axes/leaf_specs).
                  The optimizer update runs outside in plain auto-SPMD
                  (elementwise math).
  blocked scope : ONE full-manual shard_map over EVERY mesh axis, with
                  all axes acting as FSDP worker axes (a 'model' axis
                  is folded into the worker set — launch.mesh
                  worker_axes(scope="blocked")).  FSDP params +
                  aggregation inside the backward scan (core.blocked)
                  — the >20B path.  Any registered aggregator runs
                  per-bucket; each bucket's real n_selected rides out
                  of the backward on a selection token's cotangent (a
                  histogram over counts), so the n_selected /
                  n_selected_min metrics are truthful — the seed
                  hard-coded n_selected == m here.

The builder returns the jitted step plus the sharding trees needed by
both the real driver and the dry-run (which feeds ShapeDtypeStructs).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ByzantineConfig, ModelConfig, TrainConfig
from ..core import threat
from ..core.blocked import key_carrier, make_fsdp_agg_barrier, selection_token
from ..core.distributed import robust_aggregate
from ..launch.mesh import n_workers, worker_axes
from ..models import params as PM
from ..models import transformer as TF
from ..optim import get_optimizer

GIANT_PARAMS = 20e9


def resolve_strategy(tcfg: TrainConfig) -> tuple[str, str]:
    """(scope, layout) with 'auto' resolved by model size.

    Global-scope ``agg_layout="auto"`` stays "auto": the engine scores
    gather vs a2a PER LEAF at trace time through the analytic cost
    model (analysis.costmodel.plan_layouts — big leaves → a2a, tiny
    leaves → gather, stat-free mean → the replicated fast path) and
    logs the resolved plan.  The blocked scope runs its per-bucket a2a
    barrier regardless; explicit "gather"/"a2a" force a uniform layout
    (the paper baseline / EXPERIMENTS.md §Perf pair 2 setting)."""
    n = PM.count_params(TF.param_defs(tcfg.model))
    scope = tcfg.agg_scope
    if scope == "auto":
        scope = "blocked" if n > GIANT_PARAMS else "global"
    layout = tcfg.agg_layout
    if layout == "auto" and scope == "blocked":
        layout = "a2a"
    return scope, layout


class StepBundle(NamedTuple):
    step_fn: object             # jitted (params, opt, batch, step, key) -> ...
    param_specs: object         # PartitionSpec pytree
    opt_specs: object
    batch_specs: dict
    scope: str
    layout: str

    def shardings(self, mesh):
        to_sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        return to_sh(self.param_specs), to_sh(self.opt_specs), to_sh(self.batch_specs)


def _opt_state_specs(opt_name: str, pspecs):
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return pspecs
    if opt_name == "adamw":
        return {"m": pspecs, "v": pspecs}
    raise ValueError(opt_name)


def _layer_slice_specs(specs):
    """Drop the leading stack-dim entry of every leaf spec (the scan
    consumes it)."""
    return jax.tree.map(lambda s: P(*s[1:]) if len(s) else s, specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs_for(cfg: ModelConfig, waxes) -> dict:
    w = tuple(waxes) if len(waxes) > 1 else waxes[0]
    out = {"tokens": P(w)}
    if cfg.n_prefix_tokens:
        out["prefix_embed"] = P(w)
    return out


def _local_batch(batch):
    """Squeeze the (locally size-1) sharded worker axis."""
    return {k: v.reshape(v.shape[1:]) if v.shape[0] == 1 else v[0]
            for k, v in batch.items()}


def _build_blocked_step(tcfg, mesh, opt, layout):
    """One FULL-manual shard_map over every mesh axis: FSDP params,
    per-bucket aggregation inside the backward scan."""
    cfg = tcfg.model
    bcfg = tcfg.byzantine
    waxes = worker_axes(mesh, "blocked")            # every axis
    m = n_workers(mesh, "blocked")
    defs = TF.param_defs(cfg)
    # tp=False: the 'model' axis acts as extra FSDP workers here, never
    # as tensor parallelism — the whole step is manual over it
    pspecs = PM.pspec_tree(defs, mesh, fsdp=True, tp=False)
    ospecs = _opt_state_specs(tcfg.optimizer, pspecs)
    bspecs = batch_specs_for(cfg, waxes)
    remat = tcfg.remat == "block"
    metric_spec = P()
    elastic = bcfg.elastic
    guard = tcfg.recovery.guard
    # the per-step active mask is a TRACED [m] f32 arg (replicated):
    # one compiled step serves every active set up to m slots —
    # changing who straggles never recompiles (DESIGN.md §Elastic).
    # The guard (§Faults) adds a second traced [m] vector — the grad
    # fault mask — and a per-worker finiteness metric; both replicated,
    # so fault churn never recompiles either.
    extra = (P(), P()) if guard else ((P(),) if elastic else ())
    mspecs = {"loss": metric_spec, "ce": metric_spec,
              "gnorm": metric_spec, "n_selected": metric_spec,
              "n_selected_min": metric_spec}
    if guard:
        mspecs["worker_ok"] = metric_spec

    @partial(shard_map, mesh=mesh,
             in_specs=(pspecs, ospecs, bspecs, P(), P(), *extra),
             out_specs=(pspecs, ospecs, mspecs),
             axis_names=set(waxes), check_vma=False)
    def step(params, opt_state, batch, step_idx, key, *rest):
        activef = rest[0] if elastic else None
        faultf = rest[1] if guard else None
        lbatch = _local_batch(batch)
        lspecs = {k: _layer_slice_specs(v) for k, v in pspecs.items()
                  if k.startswith("seg_")}
        top_specs = {k: v for k, v in pspecs.items()
                     if not k.startswith("seg_")}
        # every barrier receives the RAW step key (key_carrier);
        # the bucket name (static, folded inside the barrier bwd)
        # and the scan index decorrelate the injected noise across
        # buckets and layers, while byzantine membership is drawn
        # from the unfolded key so all buckets corrupt ONE worker
        # set (threat.membership_mask, incl. the resample policy)
        barriers = {k: make_fsdp_agg_barrier(v, bcfg, waxes, k,
                                             elastic=elastic)
                    for k, v in lspecs.items()}
        top_barrier = make_fsdp_agg_barrier(top_specs, bcfg, waxes, "top",
                                            elastic=elastic)
        keyf = key_carrier(key)
        toks = {k: selection_token(m) for k in (*barriers, "top")}

        def lfn(params, toks):
            if elastic:
                hooks = {k: (lambda p, i, b=b, t=toks[k]:
                             b(p, t, i, keyf, activef))
                         for k, b in barriers.items()}
                top_hook = lambda p: top_barrier(
                    p, toks["top"], jnp.float32(0), keyf, activef)
            else:
                hooks = {k: (lambda p, i, b=b, t=toks[k]: b(p, t, i, keyf))
                         for k, b in barriers.items()}
                top_hook = lambda p: top_barrier(
                    p, toks["top"], jnp.float32(0), keyf)
            loss, met = TF.loss_fn(cfg, params, lbatch, remat=remat,
                                   seg_hooks=hooks, top_hook=top_hook)
            if guard:
                # fault injection rides the LOSS inside the
                # differentiated function: autodiff propagates the NaN
                # into this worker's entire gradient, exactly like a
                # real fp blow-up on the device would
                f = faultf[jax.lax.axis_index(waxes)]
                loss = loss * jnp.where(f > 0, jnp.float32(jnp.nan),
                                        jnp.float32(1.0))
            return loss, met

        (loss, met), (agg, tgrads) = jax.value_and_grad(
            lfn, argnums=(0, 1), has_aux=True)(params, toks)
        # each token's cotangent is one_hot(n_selected) per barrier
        # call; gradient accumulation sums them over buckets and
        # scan iterations into one histogram over counts 0..m
        sel_hist = sum(jax.tree.leaves(tgrads))

        new_params, new_opt = opt.update(agg, opt_state, params, step_idx)
        # fsdp-sharded leaves need a cross-worker psum; replicated
        # leaves are already global.
        from ..core.blocked import _fsdp_dim
        ss_f = jnp.float32(0)
        ss_r = jnp.float32(0)
        for g, s in zip(jax.tree.leaves(agg),
                        jax.tree.leaves(pspecs,
                                        is_leaf=lambda x: isinstance(x, P))):
            ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
            if _fsdp_dim(s, waxes) is not None:
                ss_f += ss
            else:
                ss_r += ss
        gnorm = jnp.sqrt(jax.lax.psum(ss_f, waxes) + ss_r)
        # stats were psum'd before the (replicated) selection, so the
        # histogram is identical on every worker — no further
        # cross-worker reduction needed
        counts = jnp.arange(m + 1, dtype=jnp.float32)
        n_sel = (jnp.sum(counts * sel_hist)
                 / jnp.maximum(jnp.sum(sel_hist), 1.0))
        n_sel_min = jnp.argmax(sel_hist > 0).astype(jnp.float32)
        if guard:
            # per-worker finiteness, psum'd into a replicated [m]
            # vector — the supervisor's eviction signal.  Loss metrics
            # become ACTIVE-masked means with exact where-masking so
            # one NaN worker (faulted but not yet evicted, or evicted
            # but still computing) can't keep the run's loss NaN.
            idx = jax.lax.axis_index(waxes)
            ok_i = jnp.isfinite(loss).astype(jnp.float32)
            worker_ok = jax.lax.psum(
                jax.nn.one_hot(idx, m, dtype=jnp.float32) * ok_i, waxes)
            w = activef[idx] * ok_i
            denom = jnp.maximum(jax.lax.psum(w, waxes), 1.0)
            loss_m = jax.lax.psum(
                w * jnp.where(jnp.isfinite(loss), loss, 0.0), waxes) / denom
            ce_m = jax.lax.psum(
                w * jnp.where(jnp.isfinite(met["ce"]), met["ce"], 0.0),
                waxes) / denom
        else:
            loss_m = jax.lax.pmean(loss, waxes)
            ce_m = jax.lax.pmean(met["ce"], waxes)
        metrics = {
            "loss": loss_m,
            "ce": ce_m,
            "gnorm": gnorm,
            "n_selected": n_sel,
            "n_selected_min": n_sel_min,
        }
        if guard:
            metrics["worker_ok"] = worker_ok
        return new_params, new_opt, metrics

    return step, pspecs, ospecs, bspecs


def _build_global_step(tcfg, mesh, opt, layout):
    """Auto-SPMD loss region + full-manual aggregation region +
    auto-SPMD optimizer update.

    The loss is a vmap over the worker axis of the batch — NO shard_map:
    a lax.scan (the layer stack) inside a partial-manual region trips
    XLA's manual-subgroup handling, and under plain jit GSPMD shards the
    vmapped compute over the worker axes and the tensor-parallel math
    over 'model' exactly as the serving paths do.  Only the aggregation,
    which needs real worker collectives, enters manual mode — over
    EVERY axis at once."""
    cfg = tcfg.model
    bcfg = tcfg.byzantine
    waxes = worker_axes(mesh, "global")
    maxes = tuple(a for a in mesh.axis_names if a not in waxes)
    wspec = tuple(waxes) if len(waxes) > 1 else waxes[0]
    m = n_workers(mesh, "global")
    defs = TF.param_defs(cfg)
    pspecs = PM.pspec_tree(defs, mesh, fsdp=False)
    ospecs = _opt_state_specs(tcfg.optimizer, pspecs)
    bspecs = batch_specs_for(cfg, waxes)
    remat = tcfg.remat == "block"
    is_pspec = lambda x: isinstance(x, P)
    elastic = bcfg.elastic
    guard = tcfg.recovery.guard
    extra = (P(),) if elastic else ()

    # full-manual aggregation region: worker collectives in any engine
    # layout lower cleanly; leaves arrive as [1, *model-local shard]
    gb_in = jax.tree.map(lambda s: P(wspec, *s), pspecs, is_leaf=is_pspec)

    @partial(shard_map, mesh=mesh, in_specs=(gb_in, P(), *extra),
             out_specs=(pspecs, P()),
             axis_names=set(mesh.axis_names), check_vma=False)
    def agg_region(gstack, key, *rest):
        activef = rest[0] if elastic else None
        local = jax.tree.map(lambda g: g.reshape(g.shape[1:]), gstack)
        local = threat.inject(local, key, bcfg, waxes,
                              leaf_specs=pspecs, model_axes=maxes,
                              active=activef)
        agg, st = robust_aggregate(local, bcfg, waxes, layout=layout,
                                   flatten_columns=True,
                                   model_axes=maxes, leaf_specs=pspecs,
                                   valid=activef)
        if st is not None:
            n_sel = jnp.sum(st.selected.astype(jnp.float32))
        elif elastic:
            n_sel = jnp.sum((activef > 0).astype(jnp.float32))
        else:
            n_sel = jnp.float32(m)
        return agg, n_sel

    def step(params, opt_state, batch, step_idx, key, *rest):
        activef = rest[0] if elastic else None
        faultf = rest[1] if guard else None

        if guard:
            # the fault flag multiplies the LOSS inside the
            # differentiated function, so autodiff turns one flag into
            # a fully-NaN per-worker gradient — a faithful stand-in
            # for an fp blow-up on that worker's device
            def wloss(p, wbatch, f):
                loss, met = TF.loss_fn(cfg, p, wbatch, remat=remat)
                return loss * jnp.where(f > 0, jnp.float32(jnp.nan),
                                        jnp.float32(1.0)), met

            (loss, met), grads = jax.vmap(
                jax.value_and_grad(wloss, has_aux=True),
                in_axes=(None, 0, 0))(params, batch, faultf)
        else:
            def wloss(p, wbatch):
                return TF.loss_fn(cfg, p, wbatch, remat=remat)

            (loss, met), grads = jax.vmap(
                jax.value_and_grad(wloss, has_aux=True),
                in_axes=(None, 0))(params, batch)
        # pin the per-worker grad stack to [worker axes, *param sharding]
        # so the hand-off into the manual region inserts no resharding
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P(wspec, *s))),
            grads, pspecs, is_leaf=is_pspec)
        agg, n_sel = agg_region(grads, key,
                                *((activef,) if elastic else ()))
        new_params, new_opt = opt.update(agg, opt_state, params, step_idx)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(agg)))
        if guard:
            # active-masked finite means + the per-worker finiteness
            # vector (the supervisor's eviction signal); exact
            # where-masking keeps one NaN worker from poisoning the
            # run's loss metric forever
            worker_ok = jnp.isfinite(loss).astype(jnp.float32)
            w = (activef > 0).astype(jnp.float32) * worker_ok
            denom = jnp.maximum(jnp.sum(w), 1.0)
            loss_m = jnp.sum(
                w * jnp.where(jnp.isfinite(loss), loss, 0.0)) / denom
            ce_m = jnp.sum(
                w * jnp.where(jnp.isfinite(met["ce"]), met["ce"],
                              0.0)) / denom
        else:
            loss_m, ce_m = jnp.mean(loss), jnp.mean(met["ce"])
        metrics = {"loss": loss_m, "ce": ce_m,
                   "gnorm": gnorm,
                   "n_selected": n_sel, "n_selected_min": n_sel}
        if guard:
            metrics["worker_ok"] = worker_ok
        return new_params, new_opt, metrics

    return step, pspecs, ospecs, bspecs


def build_train_step(tcfg: TrainConfig, mesh, jit: bool = True) -> StepBundle:
    """``jit=False`` returns the raw (unjitted) step callable — the
    static-analysis driver (``repro.launch.lint``) traces it with
    ``jax.make_jaxpr`` without a pjit wrapper around the whole step.

    When ``tcfg.byzantine`` is elastic (quorum/max_m set — DESIGN.md
    §Elastic) the returned step takes a sixth argument ``active`` ([m]
    0/1, who reached this round's quorum), defaulting to all-ones.  The
    mask is traced, so steps at m, m−2, m+2 active workers share ONE
    executable.  Passing ``active`` to a non-elastic step is an error —
    the fixed-m graphs would silently ignore it.

    With ``tcfg.recovery.guard`` (requires elastic) the step grows two
    more traced args — ``faults`` ([m] 0/1 grad-fault injection flags)
    and ``loss_ema`` (scalar, < 0 disarms the spike detector) — plus
    metrics ``worker_ok`` ([m] per-worker gradient finiteness),
    ``step_ok``, ``grad_finite`` and ``loss_spike``.  A non-finite or
    spiking step returns the INPUT params/opt state unchanged (in-jit
    hold); the host-side supervisor (faults/supervisor.py) reads the
    metrics and decides eviction / rollback."""
    opt = get_optimizer(tcfg)
    scope, layout = resolve_strategy(tcfg)
    bcfg = tcfg.byzantine
    rcfg = tcfg.recovery
    m = n_workers(mesh, scope)
    if rcfg.guard and not bcfg.elastic:
        raise ValueError(
            "recovery.guard requires an elastic ByzantineConfig (set "
            "quorum/max_m): eviction and hold are expressed through the "
            "traced active mask")
    if bcfg.elastic:
        if bcfg.max_m and bcfg.max_m != m:
            raise ValueError(
                f"ByzantineConfig.max_m={bcfg.max_m} does not match the "
                f"mesh's {m} worker slots for scope={scope!r}")
        if bcfg.quorum > m:
            raise ValueError(
                f"ByzantineConfig.quorum={bcfg.quorum} exceeds the mesh's "
                f"{m} worker slots for scope={scope!r}")
    build = _build_blocked_step if scope == "blocked" else _build_global_step
    inner, pspecs, ospecs, bspecs = build(tcfg, mesh, opt, layout)

    # n_active is attached HERE, outside the scope builders: the blocked
    # shard_map enumerates its metric keys in out_specs, so new
    # replicated metrics belong in this wrapper (DESIGN.md §Serve
    # telemetry schema rides on it)
    if rcfg.guard:
        # in-jit detection + hold (DESIGN.md §Faults): non-finite
        # aggregate, non-finite loss, or a loss spike vs the traced EMA
        # parks BOTH params and optimizer state on their old values —
        # one fused select per leaf, no host round-trip, and because
        # active/faults/loss_ema are all traced the guard costs zero
        # recompiles across fault churn.  jnp.where is an exact select:
        # holding against a NaN candidate tree is safe.
        def step(params, opt_state, batch, step_idx, key, active=None,
                 faults=None, loss_ema=None):
            act = (jnp.ones((m,), jnp.float32) if active is None
                   else jnp.asarray(active, jnp.float32))
            flt = (jnp.zeros((m,), jnp.float32) if faults is None
                   else jnp.asarray(faults, jnp.float32))
            # EMA sentinel: < 0 disarms the spike detector (first steps)
            ema = (jnp.float32(-1.0) if loss_ema is None
                   else jnp.asarray(loss_ema, jnp.float32))
            new_p, new_o, met = inner(params, opt_state, batch,
                                      step_idx, key, act, flt)
            grad_ok = jnp.isfinite(met["gnorm"])
            loss_ok = jnp.isfinite(met["loss"])
            spike = (ema > 0) & (met["loss"] > rcfg.spike_mult * ema)
            ok = grad_ok & loss_ok & ~spike
            held_p = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                  new_p, params)
            held_o = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                  new_o, opt_state)
            met = {**met, "n_active": jnp.sum(act),
                   "step_ok": ok.astype(jnp.float32),
                   "grad_finite": grad_ok.astype(jnp.float32),
                   "loss_spike": spike.astype(jnp.float32)}
            return held_p, held_o, met
    elif bcfg.elastic:
        def step(params, opt_state, batch, step_idx, key, active=None):
            act = (jnp.ones((m,), jnp.float32) if active is None
                   else jnp.asarray(active, jnp.float32))
            params, opt_state, met = inner(params, opt_state, batch,
                                           step_idx, key, act)
            return params, opt_state, {**met, "n_active": jnp.sum(act)}
    else:
        def step(params, opt_state, batch, step_idx, key, active=None):
            if active is not None:
                raise ValueError(
                    "active mask passed to a non-elastic step; set "
                    "ByzantineConfig.quorum (or max_m) to opt in")
            params, opt_state, met = inner(params, opt_state, batch,
                                           step_idx, key)
            return params, opt_state, {**met, "n_active": jnp.float32(m)}

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1))
    return StepBundle(step, pspecs, ospecs, bspecs, scope, layout)
