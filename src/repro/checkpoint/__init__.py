from .ckpt import latest_step, restore, save
