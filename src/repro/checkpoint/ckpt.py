"""Flat-file checkpointing (orbax-free, offline-friendly).

Saves a pytree of arrays as one ``.npz`` per save plus a JSON treedef
manifest.  Arrays are gathered to host (fine at example scale; the
dry-run path never checkpoints).  Restore rebuilds the exact pytree and
optionally re-places leaves onto provided shardings.

Write protocol (the hot-swap watcher depends on it — DESIGN.md §Serve):
every file lands via temp-name + ``os.rename`` (atomic on POSIX), and
the JSON manifest is written LAST.  ``latest_step`` only reports steps
whose manifest exists, so a reader polling the directory can never
observe a torn checkpoint: either the step is invisible, or its ``.npz``
is complete.

Retention + last_good (DESIGN.md §Faults): ``save(..., keep=k)`` prunes
all but the newest ``k`` complete steps — manifest removed FIRST (the
inverse of the write protocol, so a step becomes invisible before its
npz disappears) and the ``last_good`` step is never pruned.  The
``last_good`` pointer (``mark_good``/``last_good_step``) only advances
after :func:`validate` passes, so a supervisor rolling back — or a
HotSwapper falling back — never lands on a checkpoint that merely
*exists* but cannot be restored (torn npz, manifest–npz disagreement).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        a = np.asarray(leaf)
        if a.dtype.kind == "V":
            # ml_dtypes leaf (bfloat16/fp8): npz stores it as raw void
            # and the identity is unrecoverable on load — widen to
            # float32 (lossless) and let restore cast back to like's
            # dtype
            a = a.astype(np.float32)
        out[key] = a
    return out


def _atomic_write(path: str, write_fn):
    """Write via a temp name in the same directory, then rename."""
    tmp = path + ".tmp"
    write_fn(tmp)
    os.rename(tmp, path)


def save(path: str, tree, step: int = 0, extra: Optional[dict] = None,
         keep: int = 0) -> str:
    """Atomically save ``tree`` as step ``step``; returns the npz path.

    The ``.npz`` renames into place first, the manifest last — a crash
    between the two leaves an orphan ``.npz`` that ``latest_step``
    skips (cleaned up by the next save of the same step).

    ``keep`` > 0 enables keep-last-k retention: after the save, all but
    the newest ``keep`` complete steps are pruned — except the
    ``last_good`` step, which survives regardless of age (it is the
    rollback anchor)."""
    os.makedirs(path, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    npz = os.path.join(path, f"step_{step:08d}.npz")
    _atomic_write(npz, lambda tmp: np.savez(tmp_npz(tmp), **arrays))
    manifest = {"step": step, "keys": sorted(arrays), "extra": extra or {}}
    _atomic_write(os.path.join(path, f"step_{step:08d}.json"),
                  lambda tmp: _dump_json(tmp, manifest))
    if keep > 0:
        prune(path, keep)
    return npz


def tmp_npz(tmp: str):
    """np.savez appends '.npz' unless the name already ends with it —
    hand it an open file object so the temp name is used verbatim."""
    return open(tmp, "wb")


def _dump_json(tmp: str, obj):
    with open(tmp, "w") as f:
        json.dump(obj, f)


def steps(path: str) -> list:
    """Sorted complete steps (both ``.npz`` and manifest present)."""
    if not os.path.isdir(path):
        return []
    files = set(os.listdir(path))
    return sorted(int(f[5:13]) for f in files
                  if f.startswith("step_") and f.endswith(".npz")
                  and f[:-4] + ".json" in files)


def latest_step(path: str) -> Optional[int]:
    """Newest step with BOTH the ``.npz`` and its manifest present.

    The manifest is written last, so a step visible here is complete —
    a torn write (crash mid-save) is simply not reported."""
    all_steps = steps(path)
    return all_steps[-1] if all_steps else None


LAST_GOOD_FILE = "last_good.json"


def prune(path: str, keep: int) -> list:
    """Remove all but the newest ``keep`` complete steps, never
    touching the ``last_good`` step.  The manifest goes FIRST (inverse
    of the write protocol: the step turns invisible to pollers before
    its npz disappears).  Returns the pruned step list."""
    good = last_good_step(path)
    victims = [s for s in steps(path)[:-keep] if s != good]
    for s in victims:
        for ext in (".json", ".npz"):
            try:
                os.remove(os.path.join(path, f"step_{s:08d}{ext}"))
            except FileNotFoundError:
                pass
    return victims


def validate(path: str, step: int, like=None) -> None:
    """Raise unless checkpoint ``step`` would restore cleanly: the
    manifest parses, the npz opens and every manifest key decompresses
    (a truncated npz fails here), the key sets agree, and — with
    ``like`` — they match the target tree.  Shares ``restore``'s
    failure modes without materializing the full tree placement."""
    manifest = load_manifest(path, step)
    saved = set(manifest["keys"])
    with np.load(os.path.join(path, f"step_{step:08d}.npz")) as data:
        npz_keys = set(data.files)
        if npz_keys != saved:
            raise ValueError(
                f"checkpoint step {step}: manifest/npz disagree "
                f"(manifest-only={sorted(saved - npz_keys)} "
                f"npz-only={sorted(npz_keys - saved)})")
        for k in data.files:
            data[k]          # force decompression: catches torn members
    if like is not None:
        want = set(_flatten_with_paths(like))
        if saved != want:
            raise ValueError(
                f"checkpoint step {step} does not match the target tree: "
                f"missing={sorted(want - saved)} "
                f"extra={sorted(saved - want)}")


def mark_good(path: str, step: int, like=None) -> None:
    """Advance the ``last_good`` pointer to ``step`` — but only after
    :func:`validate` passes; a torn/corrupt checkpoint raises and the
    pointer stays where it was."""
    validate(path, step, like=like)
    _atomic_write(os.path.join(path, LAST_GOOD_FILE),
                  lambda tmp: _dump_json(tmp, {"step": step}))


def last_good_step(path: str) -> Optional[int]:
    """The validated rollback anchor, or None (no pointer yet, or the
    pointed-at step has since vanished)."""
    p = os.path.join(path, LAST_GOOD_FILE)
    try:
        with open(p) as f:
            step = json.load(f)["step"]
    except (OSError, json.JSONDecodeError, KeyError, TypeError):
        return None
    return step if step in steps(path) else None


def load_manifest(path: str, step: int) -> dict:
    with open(os.path.join(path, f"step_{step:08d}.json")) as f:
        return json.load(f)


def restore(path: str, like, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like``.  ``shardings``: optional
    matching pytree of jax.sharding.Sharding for device placement.

    The saved manifest's key set is validated against the target tree
    before any array is touched — a checkpoint from a different model
    (or a renamed layer) fails loudly with the missing/extra key names
    instead of a KeyError deep in the load loop."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    manifest = load_manifest(path, step)
    want = _flatten_with_paths(like)
    saved_keys = set(manifest["keys"])
    want_keys = set(want)
    if saved_keys != want_keys:
        missing = sorted(want_keys - saved_keys)
        extra = sorted(saved_keys - want_keys)
        raise ValueError(
            f"checkpoint step {step} under {path} does not match the "
            f"target tree: missing={missing} extra={extra}")
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))
    npz_keys = set(data.files)
    if npz_keys != saved_keys:
        raise ValueError(
            f"checkpoint step {step}: manifest/npz disagree "
            f"(manifest-only={sorted(saved_keys - npz_keys)} "
            f"npz-only={sorted(npz_keys - saved_keys)}) — torn write?")
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    sh_flat = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(flat))
    for (pathk, leaf), sh in zip(flat, sh_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out), step
