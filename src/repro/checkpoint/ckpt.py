"""Flat-file checkpointing (orbax-free, offline-friendly).

Saves a pytree of arrays as one ``.npz`` per save plus a JSON treedef
manifest.  Arrays are gathered to host (fine at example scale; the
dry-run path never checkpoints).  Restore rebuilds the exact pytree and
optionally re-places leaves onto provided shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, step: int = 0, extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(path, f"step_{step:08d}.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays), "extra": extra or {}}
    with open(os.path.join(path, f"step_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("step_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(path: str, like, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like``.  ``shardings``: optional
    matching pytree of jax.sharding.Sharding for device placement."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))
    saved = _flatten_with_paths(like)  # for key order/shape check
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    sh_flat = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(flat))
    for (pathk, leaf), sh in zip(flat, sh_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out), step
