"""Property-based tests (hypothesis) on the aggregation invariants.

hypothesis is an optional test dependency (requirements-test.txt); the
module skips cleanly where it is not installed instead of breaking
collection for the whole suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.configs.base import ByzantineConfig
from repro.core import aggregators as A
from repro.kernels import ref

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def matrices(min_m=3, max_m=24, min_d=1, max_d=120):
    return st.integers(min_m, max_m).flatmap(
        lambda m: st.integers(min_d, max_d).flatmap(
            lambda d: hnp.arrays(
                np.float32, (m, d),
                elements=st.floats(-100, 100, width=32,
                                   allow_nan=False, allow_infinity=False))))


@given(matrices())
def test_median_bounded_by_extremes(G):
    med = np.asarray(ref.cwise_median_ref(jnp.asarray(G)))
    assert (med >= G.min(axis=0) - 1e-5).all()
    assert (med <= G.max(axis=0) + 1e-5).all()


@given(matrices())
def test_scores_bounded_by_d_and_majority(G):
    m, d = G.shape
    sc = np.asarray(ref.majority_score_ref(jnp.asarray(G)))
    assert (sc >= 0).all() and (sc <= d).all()
    # per column, the majority subset has >= ceil(m/2) members, so the
    # total score mass is at least d * ceil(m/2)
    assert sc.sum() >= d * ((m + 1) // 2) - 1e-5


@given(matrices())
def test_worker_permutation_equivariance(G):
    """Permuting workers permutes scores/l1 and leaves the aggregate
    invariant (the selection is order-free)."""
    rng = np.random.default_rng(0)
    perm = rng.permutation(G.shape[0])
    Gp = G[perm]
    cfg = ByzantineConfig()
    agg, st = A.brsgd(jnp.asarray(G), cfg, return_state=True)
    agg_p, st_p = A.brsgd(jnp.asarray(Gp), cfg, return_state=True)
    np.testing.assert_allclose(np.asarray(st.scores)[perm],
                               np.asarray(st_p.scores), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.l1)[perm],
                               np.asarray(st_p.l1), rtol=1e-4, atol=1e-3)
    # aggregates agree whenever the selected sets map to each other (ties
    # in the score order can legitimately flip selections)
    if (np.asarray(st.selected)[perm] == np.asarray(st_p.selected)).all():
        np.testing.assert_allclose(np.asarray(agg), np.asarray(agg_p),
                                   rtol=1e-4, atol=1e-4)


@given(matrices(), st.floats(0.1, 10.0))
def test_positive_scale_equivariance(G, c):
    """brsgd(c·G) = c·brsgd(G) under the auto threshold (all statistics
    are positively homogeneous)."""
    cfg = ByzantineConfig(threshold=0.0)
    a1 = np.asarray(A.brsgd(jnp.asarray(G), cfg))
    a2 = np.asarray(A.brsgd(jnp.asarray(G * np.float32(c)), cfg))
    np.testing.assert_allclose(a2, c * a1, rtol=1e-3, atol=1e-3 * c)


@given(matrices())
def test_aggregate_within_row_convex_hull(G):
    """The BrSGD output is a mean of selected rows, hence inside the
    coordinate-wise hull of G."""
    agg = np.asarray(A.brsgd(jnp.asarray(G), ByzantineConfig()))
    assert (agg >= G.min(axis=0) - 1e-4).all()
    assert (agg <= G.max(axis=0) + 1e-4).all()


@given(matrices(min_m=4), st.integers(1, 3))
def test_trimmed_mean_ignores_k_outliers(G, k):
    m = G.shape[0]
    if 2 * k >= m - 1:
        return
    Gb = G.copy()
    Gb[:k] = 1e6  # k wild rows
    out = np.asarray(ref.trimmed_mean_ref(jnp.asarray(Gb), (k + 0.01) / m))
    assert np.abs(out).max() < 2e5  # outliers trimmed, not averaged in


@given(matrices())
def test_masked_mean_full_mask_is_mean(G):
    out = np.asarray(ref.masked_mean_ref(jnp.asarray(G),
                                         jnp.ones(G.shape[0], bool)))
    np.testing.assert_allclose(out, G.mean(axis=0), rtol=1e-4, atol=1e-4)


def tie_heavy_vectors(min_m=3, max_m=32):
    """1-D vectors drawn from a tiny value pool — adversarially many
    exact ties, the regime where quantile index conventions and
    counting-rank predicates disagree if either is off by one."""
    return st.integers(min_m, max_m).flatmap(
        lambda n: st.integers(1, 4).flatmap(
            lambda k: st.lists(
                st.sampled_from([-1.5, 0.0, 0.25, 7.0][:k]),
                min_size=n, max_size=n))).map(
                    lambda xs: np.asarray(xs, np.float32))


@given(tie_heavy_vectors(), st.floats(0.0, 1.0))
def test_rank_select_matches_quantile_nearest_on_ties(x, q):
    """ref.rank_select (the sort-free counting quantile that replaced
    jnp.quantile in the BrSGD selection) must agree with
    jnp.quantile(method='nearest') — including on tie-heavy inputs and
    at the .5 rounding boundary pinned by quantile_nearest_index."""
    m = x.shape[0]
    k = ref.quantile_nearest_index(q, m)
    got = float(ref.rank_select(jnp.asarray(x), k))
    want = float(jnp.quantile(jnp.asarray(x), q, method="nearest"))
    assert got == want, (x.tolist(), q, k, got, want)


@given(tie_heavy_vectors(), st.integers(0, 31))
def test_rank_select_equals_sorted_index(x, k):
    m = x.shape[0]
    k = k % m
    got = float(ref.rank_select(jnp.asarray(x), k))
    want = float(np.sort(x)[k])
    assert got == want, (x.tolist(), k, got, want)


@given(matrices(min_d=2),
       st.lists(st.integers(1, 200), min_size=1, max_size=5))
def test_fused_stats_additive_over_arbitrary_splits(G, cuts):
    """The engine.leaf_stats contract: every statistic of the fused
    pass is additive over ARBITRARY disjoint dimension splits — the
    property the gather/a2a/blocked layouts rely on when they sum
    per-leaf / per-shard / per-model-shard partials (+psum).  Scores
    are 0/1 indicator sums, so they must be exactly equal."""
    from repro.kernels import ops
    m, d = G.shape
    bounds = sorted({c % d for c in cuts} | {0, d})
    slices = [slice(a, b) for a, b in zip(bounds, bounds[1:])]
    needs = tuple(sorted(ref.STAT_NAMES))
    whole = ops.fused_stats(jnp.asarray(G), needs)
    parts = [ops.fused_stats(jnp.asarray(G[:, s]), needs) for s in slices]
    for k in needs:
        summed = sum(np.asarray(p[k]) for p in parts)
        np.testing.assert_allclose(summed, np.asarray(whole[k]),
                                   rtol=1e-4, atol=1e-3, err_msg=k)
    np.testing.assert_array_equal(
        sum(np.asarray(p["scores"]) for p in parts),
        np.asarray(whole["scores"]))


@given(matrices(min_m=3, max_m=12, min_d=1, max_d=40), st.data())
def test_streaming_fold_bitexact_with_bulk(G, data):
    """The elastic streaming-accumulator contract (DESIGN.md §Elastic):
    folding fused_stats partials over an ARBITRARY permutation and
    partition of the worker axis — including workers that never arrive
    (masked out) — is BIT-exact with the bulk masked leaf_stats pass,
    for every subset of STAT_NAMES.  Arrival order must not change a
    single ulp of any statistic, or quorum aggregation would depend on
    who straggled."""
    from repro.core import engine
    m, d = G.shape
    needs = tuple(sorted(data.draw(
        st.sets(st.sampled_from(ref.STAT_NAMES), min_size=1))))
    perm = data.draw(st.permutations(list(range(m))))
    n_arrived = data.draw(st.integers(1, m))
    arrived = perm[:n_arrived]
    cuts = (sorted(data.draw(st.sets(st.integers(1, n_arrived - 1),
                                     max_size=3)))
            if n_arrived > 1 else [])
    bounds = [0, *cuts, n_arrived]
    arrival = np.zeros((len(bounds) - 1, m), np.float32)
    for b, (a, e) in enumerate(zip(bounds, bounds[1:])):
        arrival[b, arrived[a:e]] = 1.0
    valid = arrival.sum(axis=0)

    state = engine.stream_leaf_stats(jnp.asarray(G), needs, m,
                                     jnp.asarray(arrival))
    bulk = engine.leaf_stats(jnp.asarray(G), needs, m, use_pallas=False,
                             valid=jnp.asarray(valid))
    for k in needs:
        np.testing.assert_array_equal(np.asarray(state.stats[k]),
                                      np.asarray(bulk[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(state.valid), valid)


@given(matrices(min_m=3, max_m=12, min_d=1, max_d=40), st.data())
def test_streaming_fold_survives_mid_stream_drops(G, data):
    """The fault-tolerance corollary of the streaming contract
    (DESIGN.md §Faults): workers that CRASH mid-stream — scheduled to
    arrive in a later bucket but never delivered — must leave the fold
    bit-exact with the bulk pass over the post-fault validity mask.
    The accumulator state never has to be rebuilt or corrected when a
    host dies between arrival buckets; the crash simply edits which
    rows ever fold in."""
    from repro.core import engine
    m, d = G.shape
    needs = tuple(sorted(data.draw(
        st.sets(st.sampled_from(ref.STAT_NAMES), min_size=1))))
    perm = data.draw(st.permutations(list(range(m))))
    n_sched = data.draw(st.integers(2, m))
    sched = perm[:n_sched]
    cuts = sorted(data.draw(st.sets(st.integers(1, n_sched - 1),
                                    max_size=3)))
    bounds = [0, *cuts, n_sched]
    arrival = np.zeros((len(bounds) - 1, m), np.float32)
    bucket_of = {}
    for b, (a, e) in enumerate(zip(bounds, bounds[1:])):
        arrival[b, sched[a:e]] = 1.0
        for w in sched[a:e]:
            bucket_of[w] = b
    # crash schedule: each faulted worker dies at some bucket; if it
    # dies at (or before) its scheduled arrival bucket, it never lands
    faulted = data.draw(st.sets(st.sampled_from(list(sched)),
                                min_size=1, max_size=min(3, n_sched)))
    for w in faulted:
        die_at = data.draw(st.integers(0, len(bounds) - 2))
        if die_at <= bucket_of[w]:
            arrival[bucket_of[w], w] = 0.0
    valid = arrival.sum(axis=0)
    if valid.sum() == 0:            # everyone died before arriving
        return

    state = engine.stream_leaf_stats(jnp.asarray(G), needs, m,
                                     jnp.asarray(arrival))
    bulk = engine.leaf_stats(jnp.asarray(G), needs, m, use_pallas=False,
                             valid=jnp.asarray(valid))
    for k in needs:
        np.testing.assert_array_equal(np.asarray(state.stats[k]),
                                      np.asarray(bulk[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(state.valid), valid)


@given(st.integers(2, 16), st.integers(1, 50))
def test_identical_workers_all_selected(m, d):
    """If every worker reports the same gradient, nobody is filtered and
    the aggregate is that gradient."""
    g = np.linspace(-1, 1, d).astype(np.float32)
    G = jnp.asarray(np.tile(g, (m, 1)))
    agg, st_ = A.brsgd(G, ByzantineConfig(), return_state=True)
    assert int(jnp.sum(st_.selected)) == m
    np.testing.assert_allclose(np.asarray(agg), g, atol=1e-6)
