"""Launcher-layer unit tests: strategy resolution, shape variants,
roofline math, input specs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ARCHS, ByzantineConfig, TrainConfig, get_config,
                           get_shape)
from repro.launch.roofline import PEAK_FLOPS, derive_terms, model_flops
from repro.launch.specs import variant_for_shape
from repro.training.step import resolve_strategy


def _tcfg(arch, **kw):
    return TrainConfig(model=get_config(arch), **kw)


def test_resolve_strategy_giants_blocked():
    for arch in ("deepseek-v2-236b", "dbrx-132b"):
        scope, layout = resolve_strategy(_tcfg(arch))
        assert scope == "blocked" and layout == "a2a"


def test_resolve_strategy_small_global_auto_default():
    scope, layout = resolve_strategy(_tcfg("qwen3-0.6b"))
    assert scope == "global"
    # global scope keeps "auto": the engine's per-leaf cost-model
    # planner resolves it at trace time (DESIGN.md §Cost)
    assert layout == "auto"
    # forced layouts stay selectable
    scope, layout = resolve_strategy(_tcfg("qwen3-0.6b", agg_layout="gather"))
    assert layout == "gather"
    scope, layout = resolve_strategy(_tcfg("qwen3-0.6b", agg_layout="a2a"))
    assert layout == "a2a"


def test_variant_long500k_policy():
    long = get_shape("long_500k")
    # full attention -> window 8192
    assert variant_for_shape(get_config("nemotron-4-15b"), long).attention.window == 8192
    assert variant_for_shape(get_config("dbrx-132b"), long).attention.window == 8192
    # MLA / attention-free keep native paths
    assert variant_for_shape(get_config("deepseek-v2-236b"), long).attention.window == 0
    assert variant_for_shape(get_config("rwkv6-7b"), long).attention.window == 0
    # hybrid: the mamba layers are O(1)-state, but the SHARED gqa block
    # still needs the window at 500k
    assert variant_for_shape(get_config("zamba2-2.7b"), long).attention.window == 8192
    # other shapes untouched
    assert variant_for_shape(get_config("nemotron-4-15b"),
                             get_shape("train_4k")).attention.window == 0


def test_derive_terms_dominance_and_mfu():
    # pure-compute case
    r = derive_terms(flops_per_dev=197e12, bytes_per_dev=1.0,
                     coll_bytes_per_dev=1.0, chips=2, model_fl=197e12)
    assert r["dominant"] == "compute"
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["useful_ratio"] - 0.5) < 1e-9   # 197e12 of 2x197e12 total
    # collective-bound case
    r = derive_terms(1.0, 1.0, 50e9 * 3, chips=1, model_fl=1.0)
    assert r["dominant"] == "collective" and abs(r["bound_s"] - 3.0) < 1e-9


def test_model_flops_modes():
    cfg = get_config("qwen3-0.6b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    assert tr == pytest.approx(3 * pf * (256 * 4096) / (32 * 32768))
    # decode: one token per sequence
    assert dc == pytest.approx(pf * 128 / (32 * 32768))


def test_train_inputs_shapes_divide_production_mesh():
    """Every (arch, train shape) satisfies the worker divisibility the
    dry-run depends on, for both meshes."""
    shape = get_shape("train_4k")
    for workers in (16, 32):        # single / multi pod worker counts
        assert shape.global_batch % workers == 0
    # decode batch divisibility
    assert get_shape("decode_32k").global_batch % 16 == 0


def test_all_archs_have_positive_params_and_source():
    from repro.models import transformer as TF
    from repro.models.params import count_params
    for name, cfg in ARCHS.items():
        assert cfg.source, name
        assert count_params(TF.param_defs(cfg)) > 1e8, name


# ---------------------------------------------------------------------------
# hlo_stats dtype table — the byte accounting every cost/bytes number
# rides on (roofline imports it; private per-module maps are banned)
# ---------------------------------------------------------------------------

def test_dtype_bytes_table_and_aliases():
    import numpy as np
    from repro.launch import hlo_stats as hs
    from repro.launch.roofline import dtype_bytes as roofline_db
    assert roofline_db is hs.dtype_bytes        # one table, one module
    assert hs.dtype_bytes("f32") == 4
    assert hs.dtype_bytes("bf16") == 2
    assert hs.dtype_bytes("s4") == 0.5          # sub-byte packing
    assert hs.dtype_bytes("f8e4m3fn") == 1
    assert hs.dtype_bytes("token") == 0         # ordering artifact
    # numpy spellings and dtype objects resolve through the alias map
    assert hs.dtype_bytes("float32") == 4
    assert hs.dtype_bytes(np.dtype("int8")) == 1
    assert hs.dtype_bytes(np.dtype(np.float16)) == 2


def test_dtype_bytes_unknown_is_loud():
    import pytest
    from repro.launch import hlo_stats as hs
    with pytest.raises(KeyError, match="register_dtype"):
        hs.dtype_bytes("f12weird")
    # _dims: a dtype-shaped token missing from the table must raise
    # (silent skipping is what used to undercount collective_bytes) ...
    with pytest.raises(ValueError, match="register_dtype"):
        hs._dims("f12weird[8,128]")
    # ... while non-type tokens (attribute text) stay silently skipped
    assert hs._dims("replica_groups=[4,2]") == []
    assert hs._dims("dimensions=[0]") == []


def test_register_dtype_escape_hatch():
    from repro.launch import hlo_stats as hs
    assert "f12weird" not in hs.DTYPE_BYTES
    try:
        hs.register_dtype("f12weird", 1.5)
        assert hs.dtype_bytes("f12weird") == 1.5
        assert hs._dims("f12weird[4]") == [("f12weird", [4])]
        assert hs._type_bytes("f12weird[4]") == 6.0
    finally:
        del hs.DTYPE_BYTES["f12weird"]
