"""Threat-model engine: registry contract, membership policies, and the
dense↔gather↔a2a↔blocked attack-parity matrix.

One AttackSpec per attack executes in every scope (core/threat.py); the
parity tests pin the per-worker shard_map injection and the blocked
barrier injection to the dense [m, d] execution of the SAME registry
entry — including ``alie``/``ipm``, which the seed rejected with
``ValueError`` in every distributed and blocked run.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import meshes
from conftest import run_multidevice
from repro.configs.base import ByzantineConfig
from repro.core import threat

# ---------------------------------------------------------------------------
# registry contract (in-process)
# ---------------------------------------------------------------------------


def test_registry_covers_all_shipped_attacks():
    names = threat.registered()
    assert len(names) >= 7
    for a in ("gaussian", "negation", "scale", "sign_flip", "alie", "ipm"):
        assert threat.get_spec(a).scope == "gradient", a
    assert threat.get_spec("label_flip").scope == "data"
    assert threat.get_spec("label_flip").corrupt_labels(3, 10) == 6


def test_spec_validation():
    with pytest.raises(ValueError):        # gradient spec without corrupt
        threat.AttackSpec("bad")
    with pytest.raises(ValueError):        # data spec with corrupt
        threat.AttackSpec("bad", scope="data", corrupt=lambda *a: None)
    with pytest.raises(ValueError):        # unknown knowledge stat
        threat.AttackSpec("bad", knows=frozenset({"nope"}),
                          corrupt=lambda *a: None)
    with pytest.raises(ValueError):        # unknown scope
        threat.AttackSpec("bad", scope="wire", corrupt=lambda *a: None)
    with pytest.raises(KeyError):
        threat.get_spec("no_such_attack")


def test_membership_policies():
    m = 12
    pre = ByzantineConfig(attack="scale", alpha=0.25)
    np.testing.assert_array_equal(
        np.asarray(threat.membership_mask(pre, m)), np.arange(m) < 3)
    # random: fixed subset of the right size, a function of byz_seed only
    ran = ByzantineConfig(attack="scale", alpha=0.25, membership="random",
                          byz_seed=7)
    m1 = np.asarray(threat.membership_mask(ran, m))
    m2 = np.asarray(threat.membership_mask(ran, m, jax.random.PRNGKey(99)))
    assert m1.sum() == 3 and (m1 == m2).all()
    other = np.asarray(threat.membership_mask(
        ByzantineConfig(attack="scale", alpha=0.25, membership="random",
                        byz_seed=8), m))
    assert not (m1 == other).all()
    # resample: same size, identity varies with the step key
    res = ByzantineConfig(attack="scale", alpha=0.25, membership="resample")
    r1 = np.asarray(threat.membership_mask(res, m, jax.random.PRNGKey(0)))
    r2 = np.asarray(threat.membership_mask(res, m, jax.random.PRNGKey(1)))
    assert r1.sum() == r2.sum() == 3
    with pytest.raises(ValueError):        # resample needs the step key
        threat.membership_mask(res, m)
    with pytest.raises(ValueError):
        threat.membership_mask(
            ByzantineConfig(attack="scale", alpha=0.25, membership="what"), m)


def test_knowledge_additive_over_column_splits(rng):
    """hsum/hsqsum are additive over disjoint dim ranges — the property
    that lets any scope compute them per leaf/shard and psum, exactly
    like engine.leaf_stats partials."""
    m, d = 10, 60
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    mask = jnp.arange(m) < 3
    knows = frozenset(threat.KNOWLEDGE)
    whole = threat._dense_knowledge(G, mask, knows, m - 3)
    parts = [threat._dense_knowledge(G[:, s], mask, knows, m - 3)
             for s in (slice(0, 13), slice(13, 35), slice(35, 60))]
    for k in knows:
        summed = np.concatenate([np.asarray(p[k]) for p in parts])
        np.testing.assert_allclose(summed, np.asarray(whole[k]),
                                   rtol=1e-5, atol=1e-4)


def test_resample_moves_corruption_between_steps(rng):
    G = jnp.asarray(rng.normal(size=(12, 30)).astype("f4"))
    cfg = ByzantineConfig(attack="scale", alpha=0.25, membership="resample",
                          scale_factor=100.0)
    hit = []
    for s in range(2):
        Ga = threat.apply_dense(G, jax.random.PRNGKey(s), cfg)
        rows = np.flatnonzero((np.asarray(Ga) != np.asarray(G)).any(axis=1))
        assert len(rows) == 3
        hit.append(set(rows.tolist()))
    assert hit[0] != hit[1], "resample reused one byzantine set"


def test_image_pipeline_resamples_membership_per_step():
    """Regression: ImageWorkerPipeline applied the step-0 membership
    draw to the dataset at construction, so ``resample`` degenerated to
    a fixed seeded-random set.  Corruption now happens per batch() from
    a step-keyed mask (matching the LM pipeline): two steps must
    corrupt DIFFERENT worker sets, while the fixed policies stay
    fixed."""
    from repro.data.pipeline import ImageWorkerPipeline

    m, bpw = 12, 16
    byz = ByzantineConfig(attack="label_flip", alpha=0.25,
                          membership="resample")
    pipe = ImageWorkerPipeline(m, n_per_worker=32, byz=byz)
    clean = ImageWorkerPipeline(m, n_per_worker=32)

    def corrupted_workers(step):
        got = pipe.batch(step, bpw)["labels"]
        want = clean.batch(step, bpw)["labels"]
        return frozenset(np.flatnonzero((got != want).any(axis=1)).tolist())

    hit = {s: corrupted_workers(s) for s in range(4)}
    assert all(len(h) == 3 for h in hit.values()), hit
    assert len(set(hit.values())) > 1, f"resample reused one set: {hit}"
    # per-step masks match the declared membership contract exactly
    for s, h in hit.items():
        want = frozenset(np.flatnonzero(
            threat.data_membership(byz, m, s)).tolist())
        assert h == want, (s, h, want)
    # fixed policies keep one set across steps
    fixed = ByzantineConfig(attack="label_flip", alpha=0.25,
                            membership="random", byz_seed=5)
    fpipe = ImageWorkerPipeline(m, n_per_worker=32, byz=fixed)

    def fixed_workers(step):
        got = fpipe.batch(step, bpw)["labels"]
        want = clean.batch(step, bpw)["labels"]
        return frozenset(np.flatnonzero((got != want).any(axis=1)).tolist())

    assert fixed_workers(0) == fixed_workers(3)


# ---------------------------------------------------------------------------
# dense ↔ shard_map ↔ blocked parity (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def _common(mesh_name: str) -> str:
    """Mesh-matrix preamble (tests/meshes.py): 8 host devices per case
    — flat keeps the original m=8; dm runs m=4 global workers × 2
    model shards, with leaf "w" tensor-sharded over 'model' so the
    noise-view slicing (threat._noise_view) is exercised."""
    m = 8 if mesh_name == "flat" else 4
    return meshes.preamble(mesh_name, m) + textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.compat import shard_map
        from repro.configs.base import ByzantineConfig
        from repro.core import engine, threat

        rng = np.random.default_rng(0)
        key = jax.random.PRNGKey(0)
        GRAD = [n for n in threat.registered()
                if threat.get_spec(n).scope == "gradient"]
        assert "alie" in GRAD and "ipm" in GRAD

        def spec_of(n):
            # leaf "w" tensor-shards its LAST dim over 'model' (if any)
            return P(None, "model") if (n == "w" and MAXES) else None

        def inject_tree(gs, bcfg, k):
            SPECS = {n: spec_of(n) or P(*([None] * (v.ndim - 1)))
                     for n, v in gs.items()}
            @partial(shard_map, mesh=mesh,
                     in_specs=({n: P(wspec, *SPECS[n]) for n in gs}, P()),
                     out_specs={n: P(wspec, *SPECS[n]) for n in gs})
            def inj(tree, kk):
                local = {n: v.reshape(v.shape[1:]) for n, v in tree.items()}
                out = threat.inject(local, kk, bcfg, WAXES,
                                    leaf_specs=SPECS, model_axes=MAXES)
                return {n: v[None] for n, v in out.items()}
            return inj({n: jnp.asarray(v) for n, v in gs.items()}, k)
    """)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_dense_vs_shardmap_parity_all_gradient_attacks(mesh_name):
    """threat.inject inside shard_map == threat.apply_dense on the same
    G, for EVERY registered gradient attack — the seed raised
    ValueError for alie/ipm here.  Single leaf: noise keys line up, so
    even gaussian matches bit-for-bit (on the data×model mesh the
    model-sharded leaf draws full-leaf noise and slices its shard, so
    the bits still line up)."""
    code = _common(mesh_name) + textwrap.dedent("""
        g = rng.normal(size=(m, 12)).astype("f4")
        w = rng.normal(size=(m, 4, 6)).astype("f4")   # model-shardable
        for kind in GRAD:
            bcfg = ByzantineConfig(attack=kind, alpha=0.25)
            got = np.asarray(inject_tree({"g": g}, bcfg, key)["g"])
            want = np.asarray(threat.apply_dense(jnp.asarray(g), key, bcfg))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                       err_msg=kind)
        # gaussian noise keys are derived identically -> bit-exact; the
        # dense reference is UNSHARDED, so on the data×model mesh this
        # also proves the tensor-sharded leaf "w" draws
        # sharding-invariant noise (full-leaf draw + shard slice,
        # threat._noise_view)
        bcfg = ByzantineConfig(attack="gaussian", alpha=0.25)
        for name, ref in (("g", g), ("w", w)):
            got = np.asarray(inject_tree({name: ref}, bcfg, key)[name])
            want = np.asarray(threat.apply_dense(
                jnp.asarray(ref).reshape(m, -1), key, bcfg))
            np.testing.assert_array_equal(got.reshape(m, -1), want,
                                          err_msg=name)
        # membership policies hold per-worker too: the corrupted set is
        # the dense mask, not a worker-index prefix
        bcfg = ByzantineConfig(attack="scale", alpha=0.25,
                               membership="random", byz_seed=3,
                               scale_factor=50.0)
        got = np.asarray(inject_tree({"g": g}, bcfg, key)["g"])
        mask = np.asarray(threat.membership_mask(bcfg, m))
        hit = (got != g).any(axis=1)
        np.testing.assert_array_equal(hit, mask)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_multi_leaf_knowledge_parity(mesh_name):
    """Per-leaf psum'd knowledge == dense knowledge on the concatenated
    matrix for the stat-consuming attacks (per-coordinate moments are
    leafwise, so splitting the gradient into leaves changes nothing)."""
    code = _common(mesh_name) + textwrap.dedent("""
        leaves = {"a": (3, 5), "b": (17,), "c": (2, 2), "w": (4, 6)}
        gs = {n: rng.normal(size=(m,) + s).astype("f4")
              for n, s in leaves.items()}
        G = jnp.concatenate([jnp.asarray(v).reshape(m, -1)
                             for v in gs.values()], axis=1)
        for kind in ("negation", "alie", "ipm", "scale", "sign_flip"):
            bcfg = ByzantineConfig(attack=kind, alpha=0.25,
                                   negation_factor=7.0, scale_factor=7.0)
            out = inject_tree(gs, bcfg, key)
            got = np.concatenate([np.asarray(out[n]).reshape(m, -1)
                                  for n in gs], axis=1)
            want = np.asarray(threat.apply_dense(G, key, bcfg))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                       err_msg=kind)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_alie_ipm_through_aggregation_both_layouts(mesh_name):
    """Regression: the full attack->aggregate pipeline runs under
    shard_map in BOTH collective layouts for alie/ipm (the seed's
    inject_attack raised ValueError) and matches the dense path — on
    the data×model mesh with leaf "w" tensor-sharded."""
    code = _common(mesh_name) + textwrap.dedent("""
        from repro.core.distributed import robust_aggregate
        gs = {"w": rng.normal(size=(m, 4, 6)).astype("f4"),
              "b": rng.normal(size=(m, 3)).astype("f4")}
        SPECS = {n: spec_of(n) or P(*([None] * (v.ndim - 1)))
                 for n, v in gs.items()}
        G = jnp.concatenate([jnp.asarray(v).reshape(m, -1)
                             for v in gs.values()], axis=1)
        for kind in ("alie", "ipm"):
            for agg in ("brsgd", "median"):
                bcfg = ByzantineConfig(aggregator=agg, attack=kind,
                                       alpha=0.25)
                want = np.asarray(engine.aggregate_local(
                    threat.apply_dense(G, key, bcfg), bcfg))
                for layout in ("gather", "a2a"):
                    @partial(shard_map, mesh=mesh,
                             in_specs=({n: P(wspec, *SPECS[n])
                                        for n in gs}, P()),
                             out_specs={n: SPECS[n] for n in gs})
                    def run(tree, kk):
                        local = {n: v.reshape(v.shape[1:])
                                 for n, v in tree.items()}
                        local = threat.inject(local, kk, bcfg, WAXES,
                                              leaf_specs=SPECS,
                                              model_axes=MAXES)
                        return robust_aggregate(local, bcfg, WAXES,
                                                layout=layout,
                                                model_axes=MAXES,
                                                leaf_specs=SPECS)[0]
                    out = run({n: jnp.asarray(v) for n, v in gs.items()},
                              key)
                    got = np.concatenate([np.asarray(out[n]).reshape(-1)
                                          for n in gs])
                    np.testing.assert_allclose(
                        got, want, rtol=1e-4, atol=1e-5,
                        err_msg=f"{kind}/{agg}/{layout}")
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_blocked_barrier_injects_any_registered_attack(mesh_name):
    """The blocked custom-VJP barrier corrupts per-bucket gradients via
    the SAME registry entries: barrier(bwd) with the mean rule ==
    dense corrupt + mean, for alie/ipm/scale AND (bit-exact keys)
    gaussian.  The noise key folds bucket+layer inside the barrier; the
    dense reference folds the same ids.  Blocked scope folds EVERY mesh
    axis into the worker set, so on the data×model mesh m is the full
    device count."""
    code = _common(mesh_name) + textwrap.dedent("""
        from repro.core.blocked import (bucket_key, key_carrier,
                                        make_fsdp_agg_barrier,
                                        selection_token)
        bspecs = {"w": P(None)}
        kf = key_carrier(key)
        ct = rng.normal(size=(bm, 7)).astype("f4")  # per-worker gradients

        def blocked_mean(bcfg, name):
            hook = make_fsdp_agg_barrier(bspecs, bcfg, BAXES, name)
            @partial(shard_map, mesh=mesh, in_specs=(P(bspec),),
                     out_specs=P())
            def f(ct_w):
                p = {"w": jnp.zeros((7,), jnp.float32)}
                _, vjp = jax.vjp(hook, p, selection_token(bm),
                                 jnp.float32(0), kf)
                agg, _, _, _ = vjp({"w": ct_w.reshape(-1)})
                return agg["w"]
            return np.asarray(f(jnp.asarray(ct)))

        for kind in ("alie", "ipm", "scale", "gaussian"):
            bcfg = ByzantineConfig(aggregator="mean", attack=kind,
                                   alpha=0.25)
            got = blocked_mean(bcfg, "seg_0")
            # dense reference: same noise-key derivation as the barrier
            k_noise = jax.random.fold_in(bucket_key(key, "seg_0"), 0)
            Gc = threat.apply_dense(jnp.asarray(ct), k_noise, bcfg)
            want = np.asarray(engine.aggregate_local(Gc, bcfg))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5,
                                       err_msg=kind)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


def test_blocked_train_step_runs_alie():
    """Acceptance: ByzantineConfig(attack="alie") trains under
    agg_scope=blocked on the tier-1 mesh with no ValueError (the seed's
    inject_attack raised for alie/ipm in every blocked run)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh
        from repro.data.pipeline import LMWorkerPipeline

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="alie", alpha=0.25)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope="blocked", agg_layout="a2a")
        bundle = build_train_step(tcfg, mesh)
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)
        with mesh:
            for s in range(2):
                batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                         for k, v in pipe.batch(s).items()}
                params, _, met = bundle.step_fn(params, (), batch,
                                                jnp.int32(s),
                                                jax.random.fold_in(key, s))
        met = {k: float(v) for k, v in met.items()}
        assert np.isfinite(met["loss"]), met
        assert 0 < met["n_selected"] <= 8, met
        print("OK")
    """)
    assert "OK" in run_multidevice(code, timeout=560)
