"""Cost-model + layout-autotuner tests: Cost algebra, the analytic
feature/contract formulas pinned against the committed bench files, the
planner's crossover behavior, and engine ``layout="auto"`` parity
(multi-device subprocess)."""
import json
import pathlib
import textwrap

import pytest

from conftest import run_multidevice
from repro.analysis import costmodel as cm

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Cost algebra + profiles (pure, no jax)
# ---------------------------------------------------------------------------

def test_cost_algebra():
    a = cm.compute(100.0, 40.0) + cm.collective("all_gather", 32.0, 2)
    b = cm.collective("all_gather", 8.0, 1) + cm.collective("all_reduce", 16.0, 1)
    s = a + b
    assert s.flops == 100.0 and s.hbm_bytes == 40.0
    # collective() bytes are per-call × count (per-step payload totals)
    assert s.coll_bytes == {"all_gather": 72.0, "all_reduce": 16.0}
    assert s.coll_count == {"all_gather": 3.0, "all_reduce": 1.0}
    assert s.total_coll_bytes == 88.0
    doubled = 2 * s
    assert doubled.flops == 200.0
    assert doubled.coll_bytes["all_gather"] == 144.0
    assert s * 0.5 == 0.5 * s
    assert cm.ZERO + a == a
    d = s.to_dict()
    assert d["flops"] == 100.0 and d["coll_bytes"]["all_reduce"] == 16.0


def test_profile_roofline_vs_additive():
    prof = cm.HardwareProfile("x", flops=100.0, hbm_bw=10.0, coll_bw=1.0)
    c = cm.compute(200.0, 50.0) + cm.collective("all_gather", 3.0)
    # max-term roofline: collective term 3/1 + 1 latency hop dominates
    t_roof = prof.time_s(c)
    add = cm.HardwareProfile("y", flops=100.0, hbm_bw=10.0, coll_bw=1.0,
                             additive=True)
    assert add.time_s(c) > t_roof      # additive stacks all three terms
    assert t_roof >= 3.0 / 1.0
    with pytest.raises(KeyError):
        cm.get_profile("no-such-profile")


def test_trim_stack_threshold_matches_kernels():
    from repro.kernels import ref
    assert cm.TRIM_STACK_MIN_M == ref._TRIM_STACK_MIN_M


def test_trimmed_mean_refuse_cliff_is_m_driven():
    # below the stack threshold the trimmed column rule re-fuses row
    # lists; the cliff's feature split is the fusion-cone op count, so
    # it moves with m and NOT with d
    f32 = cm.compute_features("trimmed_mean", 32, 10_000, elastic=False)
    f33 = cm.compute_features("trimmed_mean", 33, 10_000, elastic=False)
    assert f32["refuse_s"] + f32["refuse_b"] > 0
    assert f33["refuse_s"] + f33["refuse_b"] == 0 and f33["sort"] > 0
    big_d = cm.compute_features("trimmed_mean", 32, 160_000, elastic=False)
    small_d = cm.compute_features("trimmed_mean", 32, 10_000, elastic=False)
    assert (big_d["refuse_b"] > 0) == (small_d["refuse_b"] > 0)


# ---------------------------------------------------------------------------
# planner behavior
# ---------------------------------------------------------------------------

def test_plan_deterministic_and_crossover():
    leaves = [(256, "f32"), (1_000, "f32"), (40_000, "f32"),
              (100_000, "f32")]
    p1 = cm.plan_layouts("krum", 8, leaves)
    p2 = cm.plan_layouts("krum", 8, leaves)
    assert p1 == p2
    # tiny leaves stay on the latency-cheap gather; big leaves take the
    # bandwidth-cheap a2a (tpu_v5e crossover ~3.5k f32 elements at m=8)
    assert p1.layouts[0] == "gather" and p1.layouts[1] == "gather"
    assert p1.layouts[2] == "a2a" and p1.layouts[3] == "a2a"
    assert not p1.fast_path


def test_plan_monotone_in_numel():
    # once a leaf size flips to a2a, every larger leaf stays a2a
    sizes = [2 ** k for k in range(4, 22)]
    picks = [cm.plan_layouts("brsgd", 8, [(n, "f32")]).layouts[0]
             for n in sizes]
    flips = sum(1 for a, b in zip(picks, picks[1:]) if a != b)
    assert flips <= 1 and picks[-1] == "a2a"


def test_plan_mean_fast_path_and_elastic():
    leaves = [(40_000, "f32")]
    p = cm.plan_layouts("mean", 8, leaves)
    assert p.fast_path and p.layouts == ("gather",)
    # elastic mean can't take the replicated pmean shortcut
    pe = cm.plan_layouts("mean", 8, leaves, elastic=True)
    assert not pe.fast_path
    pn = cm.plan_layouts("mean", 8, leaves, fast_paths=False)
    assert not pn.fast_path


def test_plan_zero_size_leaf_ties_to_gather():
    p = cm.plan_layouts("krum", 8, [(0, "f32")])
    assert p.layouts == ("gather",)


def test_expected_collectives_mixed_plan():
    from repro.core import engine
    spec = engine.get_spec("krum")
    want = engine.expected_collectives(spec, "auto", 3,
                                       plan=("a2a", "gather", "a2a"))
    # a2a: chunk a2a + unchunk all_gather per leaf; gather: one gather
    assert want == {"all_gather": 3, "all_to_all": 2}
    mean = engine.get_spec("mean")
    assert engine.expected_collectives(
        mean, "auto", 2, plan=("a2a", "a2a")) == \
        {"all_gather": 0, "all_to_all": 0}
    saved, engine.LAST_PLAN = engine.LAST_PLAN, None
    try:
        with pytest.raises(ValueError):
            engine.expected_collectives(spec, "auto", 2)
    finally:
        engine.LAST_PLAN = saved


# ---------------------------------------------------------------------------
# pinned against the committed bench files
# ---------------------------------------------------------------------------

def _bench(name):
    return json.loads((REPO / name).read_text())


def test_predicted_contracts_match_committed_matrix_exactly():
    errors = cm.validate_contracts(_bench("BENCH_contracts.json"))
    assert errors == [], "\n".join(errors)


def test_drift_gate_passes_on_committed_bench():
    errors = cm.validate_rows(_bench("BENCH_agg.json"))
    assert errors == [], "\n".join(errors)


def test_drift_gate_catches_perturbed_row():
    bench = _bench("BENCH_agg.json")
    victim = next(r for r in bench["rows"]
                  if r["layout"] == "local" and r["aggregator"] == "krum")
    victim["us_per_call"] *= 40.0
    errors = cm.validate_rows(bench)
    assert any("krum/local" in e and "drifts" in e for e in errors), errors


def test_pick_check_passes_and_catches_regression():
    bench = _bench("BENCH_agg.json")
    assert cm.validate_pick(bench) == []
    # if the planned layout regresses far past the best measured one,
    # the acceptance band fails
    for r in bench["rows"]:
        if r["layout"] == "a2a" and r["aggregator"] == "krum":
            r["us_per_call"] *= 10.0
    errors = cm.validate_pick(bench)
    assert any("krum" in e and "acceptance band" in e for e in errors), \
        errors


def test_check_bench_rejects_bad_fits(tmp_path):
    import sys
    sys.path.insert(0, str(REPO / "benchmarks"))
    import check_bench as cb
    bench = _bench("BENCH_agg.json")
    bench["fits"]["brsgd"]["m_exp"] = float("nan")
    bad = tmp_path / "BENCH_agg.json"
    bad.write_text(json.dumps(bench))
    errs = cb.check(str(bad))
    assert any("fits[brsgd]" in e for e in errs), errs
    bench = _bench("BENCH_agg.json")
    bench["elastic_overhead"]["median"] = 0.0
    bad.write_text(json.dumps(bench))
    errs = cb.check(str(bad))
    assert any("elastic_overhead[median]" in e for e in errs), errs


def test_autotune_cli_passes_in_process(capsys):
    from repro.launch import autotune
    assert autotune.main([]) == 0
    out = capsys.readouterr().out
    assert "all checks passed" in out


# ---------------------------------------------------------------------------
# engine layout="auto" parity (8 host devices, subprocess)
# ---------------------------------------------------------------------------

def test_auto_layout_matches_forced_layouts():
    """Uniform plans are bit-identical to the forced layouts; the mixed
    plan agrees numerically; elastic auto rounds run for select and
    column specs."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.configs import ByzantineConfig
        from repro.core import engine

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        m = 8
        rng = np.random.default_rng(0)
        big = jnp.asarray(rng.normal(size=(8, 40000)).astype(np.float32))
        big = big.at[6].mul(10.0)
        tiny = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        tiny = tiny.at[6].mul(10.0)
        grads = {"big": big, "tiny": tiny}
        specs = {"big": P("data"), "tiny": P("data")}

        def run(layout, agg, plan=None, valid=None):
            cfg = ByzantineConfig(aggregator=agg)
            def f(g):
                out, _ = engine.aggregate_sharded(
                    g, cfg, axes=("data",), layout=layout, plan=plan,
                    valid=valid)
                return out
            fn = shard_map(f, mesh=mesh, in_specs=(specs,),
                           out_specs=specs)
            return jax.jit(fn)(grads)

        for agg in ("krum", "median", "brsgd"):
            auto = run("auto", agg)
            assert engine.LAST_PLAN.layouts == ("a2a", "gather"), \\
                (agg, engine.LAST_PLAN)
            for forced in ("gather", "a2a"):
                u = run("auto", agg, plan=(forced,) * 2)
                f_ = run(forced, agg)
                for k in ("big", "tiny"):
                    assert np.array_equal(np.asarray(u[k]),
                                          np.asarray(f_[k])), \\
                        (agg, forced, k)
            ga, aa = run("gather", agg), run("a2a", agg)
            for k in ("big", "tiny"):
                a = np.asarray(auto[k])
                ok = (np.allclose(a, np.asarray(ga[k]), rtol=1e-5,
                                  atol=1e-6)
                      or np.allclose(a, np.asarray(aa[k]), rtol=1e-5,
                                     atol=1e-6))
                assert ok, (agg, k)

        # mean fast path: auto == forced layouts == pmean exactly
        for forced in ("gather", "a2a"):
            assert all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(run("auto", "mean").values(),
                                       run(forced, "mean").values()))

        # elastic rounds through auto (select + column specs)
        valid = jnp.array([1, 1, 1, 1, 1, 1, 0, 0], jnp.float32)
        for agg in ("krum", "median"):
            r = run("auto", agg, valid=valid)
            assert all(np.isfinite(np.asarray(v)).all()
                       for v in r.values()), agg
        print("AUTO-PARITY-OK")
    """)
    assert "AUTO-PARITY-OK" in run_multidevice(code)


def test_auto_layout_e2e_step_matches_forced():
    """build_train_step with the default agg_layout="auto": resolves a
    mixed plan and the loss trajectory is bit-identical to forced a2a
    (every lint-arch leaf that matters is past the crossover)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step, resolve_strategy
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh
        from repro.data.pipeline import LMWorkerPipeline
        from repro.core import engine

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()

        def run(agg_layout, steps=2):
            bcfg = ByzantineConfig(aggregator="brsgd", attack="gaussian",
                                   alpha=0.25)
            tcfg = TrainConfig(model=cfg, byzantine=bcfg,
                               optimizer="sgd", lr=0.1, grad_clip=0.0,
                               agg_layout=agg_layout)
            bundle = build_train_step(tcfg, mesh)
            psh, osh, bsh = bundle.shardings(mesh)
            key = jax.random.PRNGKey(0)
            params = jax.device_put(
                PM.init_params(TF.param_defs(cfg), key), psh)
            opt = ()
            pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)
            losses = []
            with mesh:
                for s in range(steps):
                    batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                             for k, v in pipe.batch(s).items()}
                    params, opt, met = bundle.step_fn(
                        params, opt, batch, jnp.int32(s),
                        jax.random.fold_in(key, s))
                    losses.append(float(met["loss"]))
            return losses

        assert resolve_strategy(TrainConfig(model=cfg)) == \\
            ("global", "auto")
        auto = run("auto")
        plan = engine.LAST_PLAN
        assert plan is not None and set(plan.layouts) == \\
            {"a2a", "gather"}, plan
        assert all(np.isfinite(auto)), auto
        a2a = run("a2a")
        assert auto == a2a, (auto, a2a)
        print("E2E-AUTO-OK")
    """)
    assert "E2E-AUTO-OK" in run_multidevice(code)
