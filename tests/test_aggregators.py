"""Unit tests for the paper's aggregation rule and the baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig
from repro.core import aggregators as A
from repro.core import threat
from repro.kernels import ref


def make_G(rng, m=20, d=500, byz=0, attack="gaussian", scale=1e4):
    """Honest rows ~ N(mu, 0.1); first `byz` rows corrupted."""
    mu = rng.normal(size=d).astype("f4")
    G = mu[None] + 0.1 * rng.normal(size=(m, d)).astype("f4")
    G = jnp.asarray(G)
    if byz:
        cfg = ByzantineConfig(attack=attack, alpha=byz / m,
                              scale_factor=scale, negation_factor=scale,
                              gaussian_std=200.0)
        G = threat.apply_dense(G, jax.random.PRNGKey(0), cfg)
    return G, jnp.asarray(mu)


# ---------------------------------------------------------------------------
# BrSGD selection mechanics
# ---------------------------------------------------------------------------

def test_brsgd_no_byzantine_close_to_mean(rng):
    G, mu = make_G(rng, byz=0)
    cfg = ByzantineConfig()
    agg, st = A.brsgd(G, cfg, return_state=True)
    # honest-only: aggregate stays within the honest concentration radius
    assert float(jnp.max(jnp.abs(agg - mu))) < 0.2
    assert int(jnp.sum(st.selected)) >= 1


@pytest.mark.parametrize("attack", ["gaussian", "negation", "scale", "sign_flip"])
@pytest.mark.parametrize("n_byz", [2, 5, 9])
def test_brsgd_rejects_attackers(rng, attack, n_byz):
    m = 20
    G, mu = make_G(rng, m=m, byz=n_byz, attack=attack)
    agg, st = A.brsgd(G, ByzantineConfig(), return_state=True)
    # aggregate must stay near the honest mean despite the attack
    honest_mean = jnp.mean(G[n_byz:], axis=0)
    assert float(jnp.max(jnp.abs(agg - honest_mean))) < 0.5, attack
    # no byzantine row may dominate the average: selected rows' values
    # must be bounded (attacks use scale 1e4..1e10)
    sel = np.asarray(st.selected)
    picked = np.asarray(G)[sel]
    assert np.abs(picked).max() < 100.0


def test_brsgd_mean_equivalence_all_selected(rng):
    """With threshold huge and beta=1, BrSGD degenerates to the mean —
    EXACTLY: both routes combine rows with the same deterministic
    sequential accumulation (ref.masked_mean_det), so no float
    tolerance is needed."""
    G, _ = make_G(rng, byz=0)
    cfg = ByzantineConfig(threshold=1e9, beta=1.0)
    agg = A.brsgd(G, cfg)
    np.testing.assert_array_equal(np.asarray(agg), np.asarray(A.mean(G)))


def test_brsgd_select_beta_fraction(rng):
    m = 16
    scores = jnp.asarray(rng.permutation(m).astype("f4"))
    l1 = jnp.ones((m,), jnp.float32)
    st = A.brsgd_select(scores, l1, beta=0.25, threshold=10.0)
    # top ceil(0.25*16)=4 scores selected
    assert int(jnp.sum(st.c2)) == 4
    assert bool(jnp.all(scores[st.c2] >= jnp.sort(scores)[m - 4]))


def test_brsgd_select_fallback_nonempty(rng):
    """A pathological threshold that empties C1 falls back to C2."""
    m = 8
    scores = jnp.arange(m, dtype=jnp.float32)
    l1 = jnp.full((m,), 100.0)
    st = A.brsgd_select(scores, l1, beta=0.5, threshold=1e-6)
    assert int(jnp.sum(st.selected)) >= 1


def test_brsgd_auto_threshold_keeps_half(rng):
    G, _ = make_G(rng, m=20, byz=5, attack="scale")
    _, st = A.brsgd(G, ByzantineConfig(threshold=0.0), return_state=True)
    # auto rule T = median(l1): at least half the workers satisfy C1
    assert int(jnp.sum(st.c1)) >= 10


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_mean_is_arithmetic_mean(rng):
    """Bit-identical to NumPy: A.mean accumulates rows in NumPy's
    sequential axis-0 order and divides behind an optimization barrier
    (XLA's reassociated reduce + reciprocal-multiply rewrite were each
    ~1 ulp off, i.e. rel ~1e-4 on near-zero coordinates)."""
    G, _ = make_G(rng)
    np.testing.assert_array_equal(np.asarray(A.mean(G)),
                                  np.asarray(G).mean(0))


def test_cwise_median_matches_numpy(rng):
    G, _ = make_G(rng, m=21)
    np.testing.assert_allclose(np.asarray(A.cwise_median(G)),
                               np.median(np.asarray(G), axis=0), atol=1e-5)


def test_trimmed_mean_removes_extremes(rng):
    G, mu = make_G(rng, m=20, byz=4, attack="scale")
    out = A.trimmed_mean(G, ByzantineConfig(trim_frac=0.25))
    assert float(jnp.max(jnp.abs(out - mu))) < 0.5


def test_krum_picks_honest_row(rng):
    m, n_byz = 20, 6
    G, mu = make_G(rng, m=m, byz=n_byz, attack="gaussian")
    out = A.krum(G, ByzantineConfig(alpha=n_byz / m))
    # krum returns one of the honest gradients
    dists = np.abs(np.asarray(G)[n_byz:] - np.asarray(out)).max(axis=1)
    assert dists.min() < 1e-5


def test_aggregate_dispatch(rng):
    G, _ = make_G(rng)
    for name in A.AGGREGATORS:
        out = A.aggregate(G, ByzantineConfig(aggregator=name, alpha=0.1))
        assert out.shape == (G.shape[1],)
        assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# attacks
# ---------------------------------------------------------------------------

def test_attack_semantics(rng):
    m, d = 10, 50
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    key = jax.random.PRNGKey(1)

    cfg = ByzantineConfig(attack="scale", alpha=0.3, scale_factor=100.0)
    Ga = threat.apply_dense(G, key, cfg)
    np.testing.assert_allclose(np.asarray(Ga[:3]), np.asarray(G[:3]) * 100.0,
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(Ga[3:]), np.asarray(G[3:]))

    cfg = ByzantineConfig(attack="negation", alpha=0.2, negation_factor=10.0)
    Gn = threat.apply_dense(G, key, cfg)
    honest = np.asarray(G[2:]).sum(0)
    np.testing.assert_allclose(np.asarray(Gn[0]), -10.0 * honest, rtol=1e-4)

    cfg = ByzantineConfig(attack="sign_flip", alpha=0.5)
    Gs = threat.apply_dense(G, key, cfg)
    np.testing.assert_allclose(np.asarray(Gs[:5]), -np.asarray(G[:5]))

    cfg = ByzantineConfig(attack="none", alpha=0.5)
    np.testing.assert_array_equal(np.asarray(threat.apply_dense(G, key, cfg)),
                                  np.asarray(G))


def test_geometric_median_robust(rng):
    G, mu = make_G(rng, m=20, byz=6, attack="scale")
    out = A.geometric_median(G)
    assert float(jnp.max(jnp.abs(out - mu))) < 0.5
    # no byzantine: close to the mean
    G2, mu2 = make_G(rng, byz=0)
    np.testing.assert_allclose(np.asarray(A.geometric_median(G2)),
                               np.asarray(G2.mean(0)), atol=0.1)


def test_multi_krum_averages_honest(rng):
    m, n_byz = 20, 5
    G, mu = make_G(rng, m=m, byz=n_byz, attack="gaussian")
    out = A.multi_krum(G, ByzantineConfig(alpha=n_byz / m))
    assert float(jnp.max(jnp.abs(out - mu))) < 0.3
    # averaging beats single-krum variance
    single = A.krum(G, ByzantineConfig(alpha=n_byz / m))
    assert (float(jnp.linalg.norm(out - mu))
            <= float(jnp.linalg.norm(single - mu)) + 1e-3)


@pytest.mark.parametrize("attack", ["alie", "ipm"])
def test_brsgd_under_literature_attacks(rng, attack):
    """ALIE/IPM are subtler than the paper's four: verify the aggregate
    stays within the honest concentration band (bias bounded) and the
    attacks do perturb the naive mean."""
    m = 20
    G, mu = make_G(rng, m=m, byz=5, attack=attack)
    agg = A.brsgd(G, ByzantineConfig())
    honest_mean = jnp.mean(G[5:], axis=0)
    naive = jnp.mean(G, axis=0)
    err_brsgd = float(jnp.linalg.norm(agg - honest_mean))
    err_naive = float(jnp.linalg.norm(naive - honest_mean))
    assert err_naive > 0.01          # the attack moved the mean
    assert err_brsgd < 2 * err_naive + 0.5   # brsgd no worse; usually better
    assert bool(jnp.isfinite(agg).all())


def test_alie_rows_near_honest_band(rng):
    """ALIE hides inside ~1.5 sigma of the honest per-coordinate spread."""
    G, _ = make_G(rng, m=20, byz=0)
    cfg = ByzantineConfig(attack="alie", alpha=0.25)
    Ga = threat.apply_dense(G, jax.random.PRNGKey(0), cfg)
    hon = np.asarray(Ga[5:])
    byz = np.asarray(Ga[:5])
    lo = hon.mean(0) - 4 * hon.std(0)
    assert (byz >= lo[None] - 1e-4).all()   # within the plausible band


def test_gaussian_attack_replaces_rows(rng):
    m, d = 10, 2000
    G = jnp.zeros((m, d))
    cfg = ByzantineConfig(attack="gaussian", alpha=0.3, gaussian_std=200.0)
    Ga = threat.apply_dense(G, jax.random.PRNGKey(2), cfg)
    byz_std = float(jnp.std(Ga[:3]))
    assert 150.0 < byz_std < 250.0
    assert float(jnp.max(jnp.abs(Ga[3:]))) == 0.0
