"""Mesh-matrix harness: one place that says WHICH CPU meshes the
distributed parity suites run on, and emits the subprocess preamble
that builds each mesh.

Every scope must hold its parity guarantees on two mesh families:

  flat  — worker-only mesh (the original tier-1 coverage): every axis
          indexes workers, nothing is tensor-sharded.
  dm    — data×model mesh with a tensor-parallel 'model' axis: the
          global scope keeps m = n_data workers and tensor-shards
          eligible leaf dims over 'model' (the aggregation region runs
          full-manual and psums model-sharded partials across shards —
          DESIGN.md §Mesh); the blocked scope folds 'model' into the
          FSDP worker set, so its m is the full device count.

Adding a mesh is one entry in :data:`MESHES` — each parametrized parity
test picks it up automatically.  The ``REPRO_TEST_MESHES`` env var
(comma list of names) restricts the matrix, so CI can split the two
families into separate jobs without a test change.

Subprocess protocol: tests render ``preamble(name, m)`` at the top of a
``conftest.run_multidevice`` snippet.  The preamble defines::

  mesh      the jax Mesh (axis types Auto)
  AXES      all mesh axis names (tuple)
  WAXES     global-scope worker axes  (== AXES minus 'model')
  MAXES     tensor-parallel axes      (== AXES minus WAXES)
  BAXES     blocked-scope worker axes (== AXES)
  m         global-scope worker count
  bm        blocked-scope worker count (== device count)
  wspec     P entry for the global worker axes (name or tuple)
  bspec     P entry for the blocked worker axes

``n_devices(name, m)`` gives the host-device count to pass through to
``run_multidevice``.
"""
import os
import textwrap

# name -> (mesh shape fn, axis names) where the shape fn maps the
# requested GLOBAL-scope worker count m to the device grid
MESHES = {
    "flat": (lambda m: (m,), ("data",)),
    "dm": (lambda m: (m, 2), ("data", "model")),
}


def mesh_names():
    """Active mesh-matrix entries (REPRO_TEST_MESHES filters)."""
    want = os.environ.get("REPRO_TEST_MESHES", "")
    names = [n.strip() for n in want.split(",") if n.strip()] or list(MESHES)
    unknown = [n for n in names if n not in MESHES]
    if unknown:
        raise ValueError(f"REPRO_TEST_MESHES: unknown meshes {unknown}; "
                         f"known: {sorted(MESHES)}")
    return names


def n_devices(name: str, m: int) -> int:
    shape_fn, _ = MESHES[name]
    n = 1
    for s in shape_fn(m):
        n *= s
    return n


def preamble(name: str, m: int) -> str:
    shape_fn, axes = MESHES[name]
    shape = shape_fn(m)
    return textwrap.dedent(f"""
        from repro.compat import P
        from repro.launch.mesh import make_mesh
        mesh = make_mesh({shape!r}, {axes!r})
        AXES = {axes!r}
        WAXES = tuple(a for a in AXES if a != "model")
        MAXES = tuple(a for a in AXES if a == "model")
        BAXES = AXES
        m = {m}
        bm = {n_devices(name, m)}
        wspec = WAXES if len(WAXES) > 1 else WAXES[0]
        bspec = BAXES if len(BAXES) > 1 else BAXES[0]
    """)
