"""Layout-aware aggregation engine: registry contract + layout parity.

The parity matrix runs every registered aggregator in both collective
layouts and compares against the local [m, d] execution of the SAME
registry entry, over the mesh matrix in ``tests/meshes.py``: a
worker-only mesh AND a data×model mesh whose 'model' axis tensor-shards
one leaf (the aggregation runs full-manual; model-sharded partials
close with a cross-shard psum).  Leaf sizes are chosen so no
model-replicated leaf is divisible by m — every a2a transfer exercises
the zero-pad score-correction path.  A second fixed 2×2 ("pod","data")
mesh covers multi-worker-axis specifics (jaxpr regressions, fast
paths).
"""
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

import meshes
from conftest import run_multidevice
from repro.configs.base import ByzantineConfig
from repro.core import aggregators as A
from repro.core import engine

# ---------------------------------------------------------------------------
# registry contract (in-process)
# ---------------------------------------------------------------------------


def test_registry_covers_all_public_aggregators():
    assert set(A.AGGREGATORS) == set(engine.registered())


def test_spec_validation():
    with pytest.raises(ValueError):        # neither select nor column
        engine.AggregatorSpec("bad")
    with pytest.raises(ValueError):        # both
        engine.AggregatorSpec("bad", select=lambda *a: None,
                              column=lambda *a: None)
    with pytest.raises(ValueError):        # unknown stat
        engine.AggregatorSpec("bad", stats=frozenset({"nope"}),
                              select=lambda *a: None)
    with pytest.raises(KeyError):
        engine.get_spec("no_such_rule")


def test_stats_declared_are_sufficient(rng):
    """Each select rule runs from exactly its declared stats (no hidden
    dependency on undeclared statistics)."""
    m, d = 8, 40
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    cfg = ByzantineConfig(alpha=0.25)
    for name in engine.registered():
        spec = engine.get_spec(name)
        if spec.select is None:
            continue
        stats = engine.leaf_stats(G, spec.stats, m)
        assert set(stats) == set(spec.stats), name
        w, _ = spec.select(stats, cfg, m)
        assert w.shape == (m,)
        assert float(jnp.sum(w)) > 0.0, name


def test_leaf_stats_additive_over_column_splits(rng):
    """Every statistic is additive over disjoint dim ranges — the
    property the gather (per-leaf) and a2a (per-shard) layouts rely on."""
    m, d = 10, 60
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    needs = frozenset(engine.STAT_NAMES)
    whole = engine.leaf_stats(G, needs, m)
    parts = [engine.leaf_stats(G[:, s], needs, m)
             for s in (slice(0, 13), slice(13, 35), slice(35, 60))]
    for k in needs:
        summed = sum(p[k] for p in parts)
        np.testing.assert_allclose(np.asarray(summed), np.asarray(whole[k]),
                                   rtol=1e-5, atol=1e-4)
    # scores are sums of 0/1 indicators: exactly equal, not just close
    np.testing.assert_array_equal(
        np.asarray(sum(p["scores"] for p in parts)),
        np.asarray(whole["scores"]))


def test_zero_pad_correction_matches_explicit_pad(rng):
    """Appending zero columns (what the a2a layout does) shifts only the
    scores, by exactly +pad per worker — pad_correction undoes it."""
    m, d, pad = 6, 21, 5
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    Gp = jnp.pad(G, ((0, 0), (0, pad)))
    needs = frozenset(engine.STAT_NAMES)
    clean = engine.leaf_stats(G, needs, m)
    padded = engine.pad_correction(engine.leaf_stats(Gp, needs, m), pad)
    for k in needs:
        np.testing.assert_allclose(np.asarray(padded[k]),
                                   np.asarray(clean[k]), rtol=1e-5, atol=1e-5)


def test_selection_state_reports_true_row_counts(rng):
    """Non-brsgd select rules surface a SelectionState so the training
    n_selected metric is truthful (krum uses exactly 1 row, multi_krum
    m - f, geomedian weights every row)."""
    m = 12
    G = jnp.asarray(rng.normal(size=(m, 30)).astype("f4"))
    cfg = ByzantineConfig(alpha=0.25)     # f = 3
    for name, want in (("krum", 1), ("multi_krum", m - 3), ("geomedian", m)):
        _, st = engine.aggregate_local(G, cfg, return_state=True,
                                       spec=engine.get_spec(name))
        assert isinstance(st, engine.SelectionState), name
        assert int(jnp.sum(st.selected)) == want, name


def test_multi_krum_n_select_override(rng):
    m = 12
    G = jnp.asarray(rng.normal(size=(m, 30)).astype("f4"))
    cfg = ByzantineConfig(alpha=0.25)
    out1 = A.multi_krum(G, cfg, n_select=1)
    single = A.krum(G, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(single),
                               rtol=1e-5, atol=1e-6)


def test_weighted_combine_handles_float_weights(rng):
    """The engine combine is a weighted mean (denominator Σw, not
    max(Σw, 1)) so continuous selection rules like geomedian are exact."""
    m, d = 5, 17
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    w = jnp.asarray(rng.random(m).astype("f4") * 0.1)    # Σw < 1
    from repro.kernels import ref
    want = (np.asarray(w) @ np.asarray(G)) / np.asarray(w).sum()
    np.testing.assert_allclose(np.asarray(ref.masked_mean_det(G, w)), want,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ref.masked_mean_ref(G, w)), want,
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# layout parity on a 2×2 CPU mesh (subprocess, 4 host devices)
# ---------------------------------------------------------------------------

PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from repro.compat import P, shard_map
    from repro.configs.base import ByzantineConfig
    from repro.core import engine
    from repro.core.aggregators import AGGREGATORS, aggregate
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("pod", "data"))
    axes = ("pod", "data")
    m = 4
    rng = np.random.default_rng(0)
    # leaf numels 15, 9, 2: none divisible by m=4, so every a2a
    # transfer zero-pads and the score correction must fire; leaf "c"
    # (numel 2 < m) exercises the degenerate 1-column chunk.
    gs = {"a": rng.normal(size=(m, 3, 5)).astype("f4"),
          "b": rng.normal(size=(m, 9)).astype("f4"),
          "c": rng.normal(size=(m, 2)).astype("f4")}
    G = jnp.concatenate([jnp.asarray(v).reshape(m, -1)
                         for v in gs.values()], axis=1)

    def sharded(cfg, layout, fast):
        @partial(shard_map, mesh=mesh,
                 in_specs=({k: P(("pod", "data")) for k in gs},),
                 out_specs=({k: P() for k in gs}, P()))
        def agg(tree):
            local = {k: v.reshape(v.shape[1:]) for k, v in tree.items()}
            out, st = engine.aggregate_sharded(local, cfg, axes,
                                               layout=layout,
                                               allow_fast_paths=fast)
            scores = getattr(st, "scores", None)
            if scores is None:
                scores = jnp.zeros((m,), jnp.float32)
            return out, scores
        out, scores = agg({k: jnp.asarray(v) for k, v in gs.items()})
        flat = np.concatenate([np.asarray(out[k]).reshape(-1) for k in gs])
        return flat, np.asarray(scores)
""")


# ---------------------------------------------------------------------------
# mesh-matrix parity (tests/meshes.py): worker-only AND data×model
# ---------------------------------------------------------------------------

def _matrix_preamble(mesh_name: str) -> str:
    """Leaf set + full-manual sharded() runner for one mesh-matrix
    entry.  Leaf "w" tensor-shards its last dim over 'model' where the
    mesh has one; "a"/"b"/"c" are model-replicated with numels 15/9/2 —
    none divisible by m=4, so every a2a transfer zero-pads and the
    score correction must fire ("c", numel 2 < m, is the degenerate
    1-column chunk)."""
    return meshes.preamble(mesh_name, 4) + textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.compat import shard_map
        from repro.configs.base import ByzantineConfig
        from repro.core import engine
        from repro.core.aggregators import AGGREGATORS, aggregate

        rng = np.random.default_rng(0)
        gs = {"a": rng.normal(size=(m, 3, 5)).astype("f4"),
              "b": rng.normal(size=(m, 9)).astype("f4"),
              "c": rng.normal(size=(m, 2)).astype("f4"),
              "w": rng.normal(size=(m, 4, 6)).astype("f4")}
        SPECS = {"a": P(None, None), "b": P(None), "c": P(None),
                 "w": P(None, "model") if MAXES else P(None, None)}
        G = jnp.concatenate([jnp.asarray(v).reshape(m, -1)
                             for v in gs.values()], axis=1)

        def sharded(cfg, layout, fast):
            @partial(shard_map, mesh=mesh,
                     in_specs=({k: P(wspec, *SPECS[k]) for k in gs},),
                     out_specs=({k: SPECS[k] for k in gs}, P()))
            def agg(tree):
                local = {k: v.reshape(v.shape[1:]) for k, v in tree.items()}
                out, st = engine.aggregate_sharded(
                    local, cfg, WAXES, layout=layout, allow_fast_paths=fast,
                    model_axes=MAXES, leaf_specs=SPECS)
                scores = getattr(st, "scores", None)
                if scores is None:
                    scores = jnp.zeros((m,), jnp.float32)
                return out, scores
            out, scores = agg({k: jnp.asarray(v) for k, v in gs.items()})
            flat = np.concatenate([np.asarray(out[k]).reshape(-1) for k in gs])
            return flat, np.asarray(scores)
    """)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_all_aggregators_layout_parity_mesh_matrix(mesh_name):
    code = _matrix_preamble(mesh_name) + textwrap.dedent("""
        for name in AGGREGATORS:
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            want = np.asarray(aggregate(G, cfg))
            for layout in ("gather", "a2a"):
                got, _ = sharded(cfg, layout, fast=False)
                # geomedian's distributed Weiszfeld runs in Gram space —
                # same fixed point, different rounding path
                tol = 1e-3 if name == "geomedian" else 1e-5
                np.testing.assert_allclose(got, want, rtol=1e-4, atol=tol,
                                           err_msg=f"{name}/{layout}")
        print("OK")
    """)
    assert "OK" in run_multidevice(code,
                                   n_devices=meshes.n_devices(mesh_name, 4))


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_brsgd_scores_integer_exact_across_layouts(mesh_name):
    """Majority scores are sums of 0/1 indicators — every layout on
    every mesh must produce the SAME integers, including through the
    a2a zero-pad correction (d % m != 0 on the replicated leaves) and
    the cross-model-shard psum on the data×model mesh."""
    code = _matrix_preamble(mesh_name) + textwrap.dedent("""
        cfg = ByzantineConfig(aggregator="brsgd")
        from repro.core.aggregators import brsgd
        _, st = brsgd(G, cfg, return_state=True)
        want = np.asarray(st.scores)
        assert (want == np.round(want)).all()
        for layout in ("gather", "a2a"):
            _, got = sharded(cfg, layout, fast=False)
            np.testing.assert_array_equal(got, want, err_msg=layout)
        print("OK")
    """)
    assert "OK" in run_multidevice(code,
                                   n_devices=meshes.n_devices(mesh_name, 4))


def test_mean_fast_path_matches_generic_engine():
    code = PARITY + textwrap.dedent("""
        cfg = ByzantineConfig(aggregator="mean")
        want = np.asarray(aggregate(G, cfg))
        for layout in ("gather", "a2a"):
            slow, _ = sharded(cfg, layout, fast=False)
            fast, _ = sharded(cfg, layout, fast=True)   # pmean
            np.testing.assert_allclose(slow, want, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(fast, want, rtol=1e-5, atol=1e-6)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_gather_layout_select_rules_gather_each_leaf_at_most_once():
    """Contract regression for the gather-free weighted combine: in the
    gather layout a select-rule aggregator emits exactly ONE all_gather
    per leaf (phase 1, fused stats) and ZERO in phase 2 — the combine
    is a weighted psum of each worker's own gradient, so no gathered
    copy crosses the phase boundary.  The seed kept every gathered leaf
    live across both phases (m× transient memory for the whole tree).
    Checked through ``repro.analysis`` (one-gather-per-leaf rule) —
    the repo's single jaxpr-walking implementation."""
    code = PARITY + textwrap.dedent("""
        from repro.analysis import trace
        from repro.analysis.rules import RuleContext, run_rules
        from repro.core.engine import get_spec

        def contract_for(cfg, fast):
            @partial(shard_map, mesh=mesh,
                     in_specs=({k: P(("pod", "data")) for k in gs},),
                     out_specs={k: P() for k in gs})
            def agg(tree):
                local = {k: v.reshape(v.shape[1:]) for k, v in tree.items()}
                return engine.aggregate_sharded(local, cfg, axes,
                                                layout="gather",
                                                allow_fast_paths=fast)[0]
            return trace(agg, {k: jnp.asarray(v) for k, v in gs.items()})

        for name in ("brsgd", "krum", "multi_krum", "geomedian"):
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            c = contract_for(cfg, True)
            ctx = RuleContext(case=name + "/gather", aggregator=name,
                              layout="gather", scope="global", m=4,
                              n_leaves=len(gs), spec=get_spec(name))
            vs = run_rules(c, ctx, rules=["one-gather-per-leaf"])
            assert not vs, [v.format() for v in vs]
            assert c.count("all_gather") == len(gs), (name, c.summary())
            assert c.count("all_reduce") >= 1, name   # weighted-psum combine
        # the stat-free select (mean, fast paths off) needs NO gather
        c = contract_for(ByzantineConfig(aggregator="mean"), False)
        ctx = RuleContext(case="mean/gather", aggregator="mean",
                          layout="gather", scope="global", m=4,
                          n_leaves=len(gs), spec=get_spec("mean"),
                          fast_paths=False)
        assert not run_rules(c, ctx, rules=["one-gather-per-leaf"])
        assert c.count("all_gather") == 0, c.summary()
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_gather_column_flatten_matches_nd_path():
    """flatten_columns routes N-D leaves through the 2-D [m, cols] view
    (Pallas-eligible) — results must match the N-D jnp path exactly."""
    code = PARITY + textwrap.dedent("""
        for name in ("median", "trimmed_mean"):
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            def run(flat):
                @partial(shard_map, mesh=mesh,
                         in_specs=({k: P(("pod", "data")) for k in gs},),
                         out_specs={k: P() for k in gs})
                def agg(tree):
                    local = {k: v.reshape(v.shape[1:])
                             for k, v in tree.items()}
                    return engine.aggregate_sharded(
                        local, cfg, axes, layout="gather",
                        flatten_columns=flat)[0]
                out = agg({k: jnp.asarray(v) for k, v in gs.items()})
                return np.concatenate([np.asarray(out[k]).reshape(-1)
                                       for k in gs])
            np.testing.assert_allclose(run(True), run(False),
                                       rtol=1e-5, atol=1e-6, err_msg=name)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_robust_aggregate_dispatches_every_aggregator():
    """The public shard_map entry point (training/step.py path) accepts
    all registered aggregators in both layouts — the seed supported 3."""
    code = PARITY + textwrap.dedent("""
        from repro.core.distributed import robust_aggregate
        for name in AGGREGATORS:
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            for layout in ("gather", "a2a"):
                @partial(shard_map, mesh=mesh,
                         in_specs=({k: P(("pod", "data")) for k in gs},),
                         out_specs={k: P() for k in gs})
                def agg(tree):
                    local = {k: v.reshape(v.shape[1:])
                             for k, v in tree.items()}
                    return robust_aggregate(local, cfg, axes, layout)[0]
                out = agg({k: jnp.asarray(v) for k, v in gs.items()})
                for k, v in gs.items():
                    assert out[k].shape == v.shape[1:], (name, layout, k)
                    assert bool(jnp.isfinite(out[k]).all()), (name, layout, k)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)
