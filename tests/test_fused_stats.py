"""Fused statistics pipeline: one-sort/one-pass parity with the
per-stat oracles, the counting (rank-select) quantile, and the
shared-row dense attack path.

The contract under test (DESIGN.md §Perf): for ANY subset of
``ref.STAT_NAMES`` the fused pass — jnp reference (one shared bitonic
sorted-rows pass) or Pallas kernel (one HBM read) — produces exactly
the statistics the independent per-stat references produce, including
on N-D worker-axis views (blocked scope keeps the worker axis mid-leaf
and never reshapes across model-sharded dims).
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig
from repro.core import engine, threat
from repro.kernels import ops, ref
from repro.kernels.brsgd_stats import fused_stats_pallas

SUBSETS = [tuple(c) for r in range(1, len(ref.STAT_NAMES) + 1)
           for c in itertools.combinations(ref.STAT_NAMES, r)]


def _oracle_stats(G, needs):
    """Independent per-stat references (the pre-fusion implementations)."""
    Gf = np.asarray(G, np.float32)
    med = np.median(Gf, axis=0)
    out = {}
    if "scores" in needs:
        out["scores"] = np.asarray(ref.majority_score_ref(G))
    if "l1" in needs:
        out["l1"] = np.abs(Gf - med).sum(axis=1)
    if "d2med" in needs:
        out["d2med"] = ((Gf - med) ** 2).sum(axis=1)
    if "gram" in needs:
        out["gram"] = Gf @ Gf.T
    return out


@pytest.mark.parametrize("needs", SUBSETS,
                         ids=["+".join(s) for s in SUBSETS])
def test_fused_ref_every_subset_matches_per_stat_oracles(rng, needs):
    m, d = 8, 300
    G = jnp.asarray((rng.normal(size=(m, d)) * 2).astype("f4"))
    got = ref.fused_stats_ref(G, needs)
    want = _oracle_stats(G, needs)
    assert set(got) == set(needs)
    for k in needs:
        np.testing.assert_allclose(np.asarray(got[k]), want[k],
                                   rtol=1e-5, atol=1e-4, err_msg=k)


@pytest.mark.parametrize("needs", SUBSETS,
                         ids=["+".join(s) for s in SUBSETS])
def test_fused_pallas_every_subset_matches_ref(rng, needs):
    """The one-HBM-read kernel == the one-sort reference, through the
    zero-pad path (d % d_blk != 0: pad columns score +1 per worker and
    contribute 0 to l1/d2med/gram)."""
    m, d = 7, 130
    G = jnp.asarray((rng.normal(size=(m, d)) * 3).astype("f4"))
    got = fused_stats_pallas(G, needs, d_blk=64)
    want = ref.fused_stats_ref(G, needs)
    for k in needs:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)
    # scores are 0/1 sums: integer-exact through the padding correction
    if "scores" in needs:
        np.testing.assert_array_equal(np.asarray(got["scores"]),
                                      np.asarray(want["scores"]))


def test_ops_fused_stats_dispatch_parity(rng):
    G = jnp.asarray(rng.normal(size=(8, 500)).astype("f4"))
    a = ops.fused_stats(G, tuple(ref.STAT_NAMES), use_pallas=True, d_blk=128)
    b = ops.fused_stats(G, tuple(ref.STAT_NAMES), use_pallas=False)
    for k in ref.STAT_NAMES:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


@pytest.mark.parametrize("shape,axis", [((3, 6, 5), 1), ((6, 4), 0),
                                        ((2, 3, 6, 2), 2), ((5, 6), 1)])
def test_fused_stats_nd_worker_axis_views(rng, shape, axis):
    """Blocked-scope worker views: the worker axis sits mid-leaf and the
    non-worker dims are never reshaped — stats must equal the flattened
    worker-major [m, cols] execution."""
    G = jnp.asarray(rng.normal(size=shape).astype("f4"))
    m = shape[axis]
    got = engine.leaf_stats(G, frozenset(ref.STAT_NAMES), m, axis=axis)
    flat = jnp.moveaxis(G, axis, 0).reshape(m, -1)
    want = engine.leaf_stats(flat, frozenset(ref.STAT_NAMES), m)
    for k in ref.STAT_NAMES:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-4, err_msg=k)


def test_sorted_worker_rows_matches_sort(rng):
    for m in (2, 3, 4, 7, 8, 20, 33):
        G = jnp.asarray(rng.normal(size=(m, 40)).astype("f4"))
        rows = ref.sorted_worker_rows(G)
        np.testing.assert_array_equal(
            np.stack([np.asarray(r) for r in rows]),
            np.sort(np.asarray(G), axis=0))
        np.testing.assert_array_equal(
            np.asarray(ref.median_from_sorted(rows)),
            np.median(np.asarray(G), axis=0))


# ---------------------------------------------------------------------------
# counting quantile (the O(m) replicated selection)
# ---------------------------------------------------------------------------

def test_rank_select_equals_sort_with_duplicates(rng):
    for m in range(2, 34):
        x = jnp.asarray(rng.integers(0, 4, m).astype("f4"))  # heavy ties
        s = np.sort(np.asarray(x))
        for k in range(m):
            assert float(ref.rank_select(x, k)) == s[k], (m, k)
    e = jnp.full((9,), 2.5)
    assert float(ref.rank_select(e, 4)) == 2.5


def test_counting_quantile_matches_jnp_nearest(rng):
    """The rank-select lower quartile reproduces jnp.quantile(...,
    method='nearest') — including the half-down tie rule at virtual
    index .5 — for every worker count the repo runs."""
    for m in range(2, 66):
        l1 = jnp.asarray(rng.normal(size=m).astype("f4") * 10)
        want = float(jnp.quantile(l1, 0.25, method="nearest"))
        got = float(ref.rank_select(l1, ref.quantile_nearest_index(0.25, m)))
        assert got == want, m


def test_brsgd_thresholds_sort_free_regression(rng):
    """brsgd_thresholds == the seed's jnp.sort/jnp.quantile formulation
    on the same inputs (the selection semantics may never drift)."""
    import math
    for m in (2, 3, 8, 16, 20, 64):
        scores = jnp.asarray(rng.integers(0, 50, m).astype("f4"))
        l1 = jnp.asarray(rng.random(m).astype("f4"))
        for beta in (0.25, 0.5, 1.0):
            kth, T = ref.brsgd_thresholds(scores, l1, beta, 0.0)
            k = max(1, math.ceil(beta * m))
            assert float(kth) == float(jnp.sort(scores)[m - k]), (m, beta)
            assert float(T) == float(jnp.quantile(l1, 0.25,
                                                  method="nearest")), m


# ---------------------------------------------------------------------------
# shared-row dense attacks
# ---------------------------------------------------------------------------

def test_shared_row_attacks_match_general_vmap_path(rng):
    """For worker-independent rules the one-evil-row broadcast must be
    bit-identical to vmapping the rule over all m rows."""
    import dataclasses
    G = jnp.asarray(rng.normal(size=(12, 40)).astype("f4"))
    key = jax.random.PRNGKey(7)
    shared = [n for n in threat.registered()
              if threat.get_spec(n).scope == "gradient"
              and threat.get_spec(n).shared_row]
    assert set(shared) == {"negation", "alie", "ipm"}
    for name in shared:
        cfg = ByzantineConfig(attack=name, alpha=0.25, negation_factor=5.0)
        spec = threat.get_spec(name)
        got = threat.apply_dense(G, key, cfg)
        byz = np.asarray(got[:3])
        np.testing.assert_array_equal(byz[1:], np.tile(byz[:1], (2, 1)))
        threat._REGISTRY[name] = dataclasses.replace(spec, shared_row=False)
        try:
            want = threat.apply_dense(G, key, cfg)
        finally:
            threat._REGISTRY[name] = spec
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shared_row_rejected_for_data_scope():
    with pytest.raises(ValueError):
        threat.AttackSpec("bad", scope="data", shared_row=True,
                          corrupt_labels=lambda y, n: y)


# ---------------------------------------------------------------------------
# benchmark schema guard
# ---------------------------------------------------------------------------

def test_committed_bench_file_passes_check_bench():
    import os
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo, "benchmarks"))
    try:
        import check_bench
    finally:
        sys.path.pop(0)
    assert check_bench.check(os.path.join(repo, "BENCH_agg.json")) == []
