"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.brsgd_stats import (brsgd_partials_pallas,
                                       brsgd_stats_pallas,
                                       cwise_median_pallas,
                                       masked_mean_pallas,
                                       select_mean_pallas,
                                       trimmed_mean_pallas)

SHAPES = [(4, 64), (8, 100), (20, 257), (20, 2048), (32, 5000), (7, 33),
          (64, 128), (3, 1)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_brsgd_stats_kernel_vs_ref(m, d, dtype):
    rng = np.random.default_rng(m * 1000 + d)
    G = jnp.asarray(rng.normal(size=(m, d)) * 3).astype(dtype)
    med, mean, sc, l1 = brsgd_stats_pallas(G, d_blk=512)
    med_r, mean_r, sc_r, l1_r = ref.brsgd_stats_ref(G)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(med), np.asarray(med_r), atol=tol)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_r), atol=tol)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l1_r),
                               rtol=1e-4, atol=tol * d)


@pytest.mark.parametrize("m,d", SHAPES)
def test_masked_mean_kernel_vs_ref(m, d):
    rng = np.random.default_rng(m + d)
    G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
    mask = jnp.asarray(rng.random(m) > 0.4)
    out = masked_mean_pallas(G, mask, d_blk=512)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.masked_mean_ref(G, mask)),
                               rtol=1e-5, atol=1e-6)


def test_masked_mean_empty_mask_is_safe():
    G = jnp.ones((4, 10))
    out = masked_mean_pallas(G, jnp.zeros((4,), bool))
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("m", [2, 3, 4, 5, 8, 16, 20, 33, 64])
def test_cwise_median_kernel_odd_even_workers(m):
    rng = np.random.default_rng(m)
    G = jnp.asarray(rng.normal(size=(m, 300)).astype("f4"))
    np.testing.assert_allclose(np.asarray(cwise_median_pallas(G, d_blk=128)),
                               np.median(np.asarray(G), axis=0), atol=1e-6)


def test_kernel_blocking_invariance():
    """Different d_blk tilings give identical results."""
    rng = np.random.default_rng(7)
    G = jnp.asarray(rng.normal(size=(12, 1000)).astype("f4"))
    outs = [brsgd_stats_pallas(G, d_blk=b) for b in (64, 256, 1000, 4096)]
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            # different tilings reduce in different orders -> f32 rounding
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


def test_ops_wrappers_pallas_matches_jnp_path():
    rng = np.random.default_rng(3)
    G = jnp.asarray(rng.normal(size=(16, 700)).astype("f4"))
    mask = jnp.asarray(rng.random(16) > 0.5)
    for a, b in zip(ops.brsgd_stats(G, use_pallas=True),
                    ops.brsgd_stats(G, use_pallas=False)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.masked_mean(G, mask, use_pallas=True)),
        np.asarray(ops.masked_mean(G, mask, use_pallas=False)), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.cwise_median(G, use_pallas=True)),
        np.asarray(ops.cwise_median(G, use_pallas=False)), atol=1e-6)


@pytest.mark.parametrize("B,H,Q,K,wlo", [(2, 3, 8, 8, 0.1),
                                         (1, 2, 32, 16, 0.3),
                                         (2, 1, 64, 64, 0.5),
                                         (1, 1, 16, 32, 0.05)])
def test_wkv6_chunk_kernel_vs_sequential_oracle(B, H, Q, K, wlo):
    """Pallas WKV6 chunk kernel (interpret mode) == per-token recurrence."""
    from repro.kernels.wkv6 import wkv6_chunk_pallas, wkv6_chunk_ref
    rng = np.random.default_rng(B * 100 + Q)
    mk = lambda: jnp.asarray(rng.normal(size=(B, H, Q, K)).astype("f4"))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(wlo, 0.999, size=(B, H, Q, K)).astype("f4"))
    u = jnp.asarray(rng.normal(size=(H, K)).astype("f4"))
    S = jnp.asarray(rng.normal(size=(B, H, K, K)).astype("f4"))
    y1, S1 = wkv6_chunk_pallas(r, k, v, w, u, S)
    y2, S2 = wkv6_chunk_ref(r, k, v, w, u, S)
    scale = max(1.0, float(jnp.abs(y2).max()))
    np.testing.assert_allclose(np.asarray(y1) / scale, np.asarray(y2) / scale,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,H,Hkv,S,D,win", [
    (1, 2, 2, 64, 16, 0),      # MHA causal
    (2, 4, 2, 128, 32, 0),     # GQA
    (1, 2, 1, 100, 16, 0),     # ragged S (padding path)
    (1, 2, 2, 256, 16, 64),    # sliding window
    (1, 1, 1, 48, 8, 16),      # small + window
])
def test_flash_attention_kernel_vs_oracle(B, H, Hkv, S, D, win):
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    rng = np.random.default_rng(S + D)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype("f4"))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype("f4"))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype("f4"))
    out = flash_attention(q, k, v, window=win, qb=32, kb=32)
    ref = flash_attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16_and_blocking_invariance():
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16))).astype(jnp.bfloat16)
    ref = flash_attention_ref(q, k, v)
    for qb, kb in ((16, 16), (32, 64), (64, 32)):
        out = flash_attention(q, k, v, qb=qb, kb=kb)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("m,d", [(8, 100), (20, 257), (7, 33), (64, 128)])
def test_brsgd_partials_kernel_matches_stats_kernel(m, d):
    """The [d]-output-free partials pass == the full stats pass."""
    rng = np.random.default_rng(m * 7 + d)
    G = jnp.asarray((rng.normal(size=(m, d)) * 2).astype("f4"))
    _, _, sc_full, l1_full = brsgd_stats_pallas(G, d_blk=64)
    sc, l1 = brsgd_partials_pallas(G, d_blk=64)
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(sc_full))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l1_full),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("beta,threshold", [(0.5, 0.0), (0.25, 0.0),
                                            (1.0, 1e9), (0.5, 1e-8)])
def test_select_mean_kernel_matches_two_pass(beta, threshold):
    """Fused select+masked-mean pass == brsgd_select + masked_mean,
    including the empty-C1∩C2 fallback (threshold 1e-8)."""
    from repro.core.engine import brsgd_select
    rng = np.random.default_rng(int(beta * 100))
    G = jnp.asarray(rng.normal(size=(16, 700)).astype("f4"))
    scores, l1 = brsgd_partials_pallas(G, d_blk=256)
    agg, w = select_mean_pallas(G, scores, l1, beta, threshold, d_blk=256)
    st = brsgd_select(scores, l1, beta, threshold)
    np.testing.assert_array_equal(np.asarray(w),
                                  np.asarray(st.selected, np.float32))
    want = masked_mean_pallas(G, st.selected, d_blk=256)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,d", [(8, 100), (20, 257), (7, 33), (10, 64)])
@pytest.mark.parametrize("trim_frac", [0.0, 0.1, 0.25, 0.45])
def test_trimmed_mean_kernel_vs_ref(m, d, trim_frac):
    rng = np.random.default_rng(m + d)
    G = jnp.asarray((rng.normal(size=(m, d)) * 3).astype("f4"))
    out = trimmed_mean_pallas(G, trim_frac, d_blk=64)   # forces padding
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.trimmed_mean_ref(G, trim_frac)),
                               rtol=1e-5, atol=1e-5)


def test_masked_mean_float_weights():
    """The kernel accepts continuous weights (engine weighted combine)."""
    rng = np.random.default_rng(5)
    G = jnp.asarray(rng.normal(size=(6, 90)).astype("f4"))
    w = jnp.asarray(rng.random(6).astype("f4") * 0.2)    # Σw < 1
    out = masked_mean_pallas(G, w, d_blk=32)
    want = (np.asarray(w) @ np.asarray(G)) / np.asarray(w).sum()
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)


def test_score_constant_column_counts_everyone():
    """A constant column splits into {all >= mean}: everyone scores 1 —
    guards the zero-padding correction in the kernel wrapper."""
    G = jnp.ones((6, 10))
    _, _, sc, l1 = brsgd_stats_pallas(G, d_blk=4)   # forces padding
    np.testing.assert_array_equal(np.asarray(sc), np.full(6, 10.0))
    np.testing.assert_allclose(np.asarray(l1), np.zeros(6), atol=1e-6)
