"""Elastic quorum aggregation (DESIGN.md §Elastic): the fixed-m
synchronous-round assumption is gone across engine, threat, blocked and
step layers.

Pins the four contracts the elastic path makes:

  * streaming fold — folding any permutation/partition of worker
    partials (``engine.stream_leaf_stats``) is BIT-exact with the bulk
    masked ``leaf_stats`` pass, for every registered aggregator's
    statistic set (arrival order must not change a single ulp).
  * masking — dropped workers contribute exact zeros, are never
    selected, and byzantine membership/counts draw over the ACTIVE set.
  * zero recompiles — one compiled step serves every active set: the
    per-step active mask is a traced argument, so running at m, m−2 and
    m+2 active workers adds ZERO cache entries after warm-up, on both
    mesh families and both scopes.
  * truthful accounting — ``n_selected`` ≤ the round's active count
    under every attack, from both scopes.

Single-host (in-process) pieces run directly; everything needing a mesh
runs via ``conftest.run_multidevice`` like the other distributed
suites.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import meshes
from conftest import run_multidevice

from repro.configs.base import ByzantineConfig
from repro.core import engine, threat
from repro.data.pipeline import ArrivalSchedule, timing_attack_spec
from repro.kernels import ref


# ---------------------------------------------------------------------------
# config validation (quorum vs honest-majority bound)
# ---------------------------------------------------------------------------

def test_config_rejects_dishonest_quorum():
    """quorum ≤ 2·n_byzantine must be rejected at construction, naming
    the bound — a quorum the attacker can majority-control is not a
    configuration, it is a defeat."""
    with pytest.raises(ValueError, match="quorum > 2\\*n_byzantine"):
        ByzantineConfig(alpha=0.5, quorum=10)
    with pytest.raises(ValueError, match="quorum > 2\\*n_byzantine"):
        ByzantineConfig(alpha=0.67, quorum=3)     # n_byz=2: 3 ≤ 4
    # boundary cases that MUST pass: n_byz drawn over the active set
    assert ByzantineConfig(alpha=0.25, quorum=10).elastic
    assert ByzantineConfig(alpha=0.25, quorum=10, max_m=20).elastic
    with pytest.raises(ValueError):
        ByzantineConfig(quorum=12, max_m=8)       # quorum exceeds slots
    with pytest.raises(ValueError):
        ByzantineConfig(quorum=-1)
    assert not ByzantineConfig(alpha=0.25).elastic


def test_config_bound_is_over_active_set():
    """The bound uses n_byzantine = floor(alpha·quorum) — the byzantine
    count of the ACTIVE set, not of max_m — so a q = 0.5·m round at
    alpha = 0.25 is legal while alpha ≥ 0.5 never is."""
    cfg = ByzantineConfig(alpha=0.25, quorum=10, max_m=20)   # q = 0.5 m
    assert cfg.quorum == 10 and cfg.elastic
    for alpha in (0.5, 0.6):
        with pytest.raises(ValueError, match="n_byzantine"):
            ByzantineConfig(alpha=alpha, quorum=10, max_m=20)


# ---------------------------------------------------------------------------
# streaming fold == bulk, every registered aggregator
# ---------------------------------------------------------------------------

def test_streaming_fold_bitexact_every_aggregator(rng):
    """For EVERY registered aggregator's statistic set: fold the
    arrival buckets of a permuted, partitioned worker set (with
    stragglers that never arrive) and compare against the bulk masked
    pass — exact array equality, no tolerance."""
    m, d = 10, 37
    G = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 10)
    for agg in engine.registered():
        spec = engine.get_spec(agg)
        needs = tuple(spec.stats)
        if not needs:
            continue        # column rules / mean: no statistics pass
        for trial in range(3):
            perm = rng.permutation(m)
            n_arrived = int(rng.integers(3, m + 1))
            arrived = perm[:n_arrived]
            n_buckets = int(rng.integers(1, n_arrived + 1))
            bucket_of = rng.integers(0, n_buckets, size=n_arrived)
            arrival = np.zeros((n_buckets, m), np.float32)
            arrival[bucket_of, arrived] = 1.0
            valid = arrival.sum(axis=0)

            state = engine.stream_leaf_stats(G, needs, m,
                                             jnp.asarray(arrival))
            bulk = engine.leaf_stats(G, needs, m, use_pallas=False,
                                     valid=jnp.asarray(valid))
            for k in needs:
                np.testing.assert_array_equal(
                    np.asarray(state.stats[k]), np.asarray(bulk[k]),
                    err_msg=f"{agg}/{k} trial {trial}")
            np.testing.assert_array_equal(np.asarray(state.valid), valid)


def test_fold_stats_is_pure_addition():
    """fold_stats is dict addition over disjoint slots — associative and
    commutative by IEEE x + 0.0 == x, the property the scan relies on."""
    m = 6
    s0 = engine.init_stream(("scores", "l1"), m)
    p1 = {"scores": jnp.zeros(m).at[1].set(3.0),
          "l1": jnp.zeros(m).at[1].set(2.0)}
    p2 = {"scores": jnp.zeros(m).at[4].set(5.0),
          "l1": jnp.zeros(m).at[4].set(7.0)}
    v1 = jnp.zeros(m).at[1].set(1.0)
    v2 = jnp.zeros(m).at[4].set(1.0)
    a = engine.fold_stats(engine.fold_stats(s0, p1, v1), p2, v2)
    b = engine.fold_stats(engine.fold_stats(s0, p2, v2), p1, v1)
    for k in a.stats:
        np.testing.assert_array_equal(np.asarray(a.stats[k]),
                                      np.asarray(b.stats[k]))
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))


# ---------------------------------------------------------------------------
# quorum selection + masked aggregation (local executor)
# ---------------------------------------------------------------------------

def test_stream_aggregate_takes_quorum_prefix(rng):
    """Selection fires once quorum workers have arrived: later arrivals
    are dropped, n_selected ≤ quorum, and the aggregate equals the
    masked local pass over exactly the quorum prefix."""
    m, d, q = 10, 29, 6
    G = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    cfg = ByzantineConfig(aggregator="brsgd", alpha=0.25, quorum=q, max_m=m)
    # arrival buckets: 4 workers, then 3, then 3 — quorum hits mid-stream
    arrival = np.zeros((3, m), np.float32)
    arrival[0, [2, 5, 7, 9]] = 1
    arrival[1, [0, 1, 3]] = 1
    arrival[2, [4, 6, 8]] = 1
    agg, st = engine.stream_aggregate(G, cfg, jnp.asarray(arrival),
                                      return_state=True)
    active = np.asarray(engine.arrival_active(jnp.asarray(arrival), q))
    assert active.sum() == q
    # the prefix by arrival order: bucket 0 fully, then 2 of bucket 1
    assert set(np.where(active > 0)[0]) == {2, 5, 7, 9, 0, 1}
    sel = np.asarray(st.selected)
    assert sel.sum() <= q
    assert not (sel & (active == 0)).any()      # late workers never selected
    want, _ = engine.aggregate_local(G, cfg, return_state=True,
                                     valid=jnp.asarray(active))
    np.testing.assert_array_equal(np.asarray(agg), np.asarray(want))


def test_masked_selection_never_selects_inactive(rng):
    """Every registered aggregator: dropped workers carry zero weight,
    the aggregate is finite, and n_selected ≤ n_active."""
    m, d = 9, 21
    G = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    valid = jnp.asarray(np.array([1, 0, 1, 1, 0, 1, 1, 0, 1], np.float32))
    na = int(np.asarray(valid).sum())
    for agg in engine.registered():
        cfg = ByzantineConfig(aggregator=agg, alpha=0.2)
        out, st = engine.aggregate_local(G, cfg, return_state=True,
                                         valid=valid)
        assert np.isfinite(np.asarray(out)).all(), agg
        sel = np.asarray(st.selected)
        assert not (sel & (np.asarray(valid) == 0)).any(), agg
        assert sel.sum() <= na, agg


def test_masked_workers_are_exact_zeros_not_poison(rng):
    """The masking contract: NaN/inf garbage in a dropped worker's row
    must not reach any statistic or the aggregate (where-masking, never
    multiplication — 0·inf = NaN)."""
    m, d = 8, 13
    G = rng.normal(size=(m, d)).astype(np.float32)
    G[3] = np.nan
    G[6] = np.inf
    valid = jnp.asarray(np.array([1, 1, 1, 0, 1, 1, 0, 1], np.float32))
    for agg in engine.registered():
        cfg = ByzantineConfig(aggregator=agg, alpha=0.2)
        out = engine.aggregate_local(jnp.asarray(G), cfg, valid=valid)
        assert np.isfinite(np.asarray(out)).all(), agg


# ---------------------------------------------------------------------------
# threat layer over the active set
# ---------------------------------------------------------------------------

def test_membership_draws_over_active_set():
    """n_byzantine = floor(alpha·n_active) and the mask never lands on a
    dropped worker, for every membership policy."""
    m = 12
    active = jnp.asarray(
        np.array([1, 1, 0, 1, 1, 1, 0, 1, 1, 0, 1, 1], np.float32))
    na = int(np.asarray(active).sum())      # 9 active
    for policy in ("prefix", "random", "resample"):
        cfg = ByzantineConfig(attack="gaussian", alpha=0.25,
                              membership=policy)
        mask = np.asarray(threat.membership_mask(
            cfg, m, key=jax.random.PRNGKey(3), active=active))
        assert mask.sum() == int(0.25 * na), policy
        assert not (mask & (np.asarray(active) == 0)).any(), policy


def test_apply_dense_never_touches_inactive(rng):
    """Gradient attacks only corrupt ACTIVE byzantine workers — a
    stalled machine cannot also submit a poisoned gradient."""
    m, d = 8, 17
    G = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    active = jnp.asarray(np.array([1, 1, 1, 1, 0, 0, 1, 1], np.float32))
    cfg = ByzantineConfig(attack="scale", alpha=0.5, membership="prefix")
    out = np.asarray(threat.apply_dense(G, jax.random.PRNGKey(0), cfg,
                                        active=active))
    changed = np.any(out != np.asarray(G), axis=1)
    assert not changed[4] and not changed[5]
    assert changed.sum() == int(0.5 * 6)    # floor(alpha · n_active)


def test_stall_attack_and_arrival_schedule():
    """The timing scope end-to-end host-side: 'stall' pins byzantine
    delays to +inf, the schedule never activates them, and honest
    stragglers fill the quorum instead."""
    m, q = 8, 6
    cfg = ByzantineConfig(attack="stall", alpha=0.25, membership="prefix",
                          quorum=q, max_m=m)
    spec = threat.get_spec("stall")
    assert spec.scope == "timing" and spec.delay is not None
    assert timing_attack_spec(cfg) is spec
    # timing attacks do not touch gradients
    assert not threat.is_gradient_attack(cfg)

    sched = ArrivalSchedule(m, q, straggle="exp", scale=0.5, byz=cfg, seed=1)
    for step in range(5):
        d = sched.delays(step)
        is_byz = threat.data_membership(cfg, m, step)
        assert np.isinf(d[is_byz]).all(), step
        act = sched.active(step)
        assert act.sum() == q, step
        assert not act[is_byz].any(), step
    # schedules are reproducible and step-keyed
    np.testing.assert_array_equal(sched.delays(3), sched.delays(3))
    assert (sched.delays(3) != sched.delays(4)).any()


def test_arrival_schedule_validation():
    with pytest.raises(ValueError, match="straggle"):
        ArrivalSchedule(8, 6, straggle="weibull")
    with pytest.raises(ValueError, match="quorum"):
        ArrivalSchedule(8, 9)
    # no straggle + no timing attack: everyone arrives at t=0, the
    # stable argsort keeps worker order for the quorum prefix
    act = ArrivalSchedule(8, 6).active(0)
    np.testing.assert_array_equal(act, [1, 1, 1, 1, 1, 1, 0, 0])


# ---------------------------------------------------------------------------
# compiled step: zero recompiles across active sets + truthful n_selected
# ---------------------------------------------------------------------------

@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
@pytest.mark.parametrize("scope", ["global", "blocked"])
def test_elastic_step_zero_recompiles_and_truthful_nsel(mesh_name, scope):
    """ONE compiled step executes at m, m−2 and m+2 active workers with
    zero recompiles: after warm-up the jit cache size must not grow as
    the active mask varies (the mask is a traced argument).  Under a
    scale attack at quorum q = 0.75·slots, n_selected stays ≤ the
    round's active count (truthful accounting) and the loss stays
    finite — from BOTH scopes on BOTH mesh families."""
    gm = 4 if mesh_name == "dm" else 8
    code = meshes.preamble(mesh_name, gm) + textwrap.dedent(f"""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.data.pipeline import LMWorkerPipeline
        from repro.launch.mesh import n_workers

        scope = {scope!r}
        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="scale",
                               alpha=0.25, membership="prefix")
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope=scope,
                           agg_layout="a2a" if scope == "global" else "auto")
        slots = n_workers(mesh, scope)
        q = max(3, int(0.75 * slots))
        bcfg = dataclasses.replace(bcfg, max_m=slots, quorum=q)
        tcfg = dataclasses.replace(tcfg, byzantine=bcfg)
        bundle = build_train_step(tcfg, mesh)
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, slots, 2, 32, byz=bcfg)

        def one(s, n_active, params):
            act = np.zeros(slots, np.float32); act[:n_active] = 1
            batch = {{k: jax.device_put(jnp.asarray(v), bsh[k])
                      for k, v in pipe.batch(s).items()}}
            params, _, met = bundle.step_fn(params, (), batch,
                                            jnp.int32(s),
                                            jax.random.fold_in(key, s),
                                            jnp.asarray(act))
            jax.block_until_ready(met["loss"])
            return params, {{k: float(v) for k, v in met.items()}}

        # nominal m = q active; the sweep runs m−2, m, m+2 (m+2 capped
        # at the slot count for the small dm-global mesh)
        counts = [q - 2, q, min(q + 2, slots)]
        with mesh:
            # warm-up to the steady-state cache (the first returned
            # params carry a different layout than device_put's — one
            # pre-existing extra entry, independent of elasticity)
            for s in range(2):
                params, met = one(s, q, params)
            steady = bundle.step_fn._cache_size()
            for s, na in enumerate(counts):
                params, met = one(2 + s, na, params)
                assert np.isfinite(met["loss"]), (na, met)
                assert met["n_selected"] <= na + 1e-6, (na, met)
                assert met["n_selected_min"] <= na + 1e-6, (na, met)
                assert met["n_selected"] > 0, (na, met)
                cs = bundle.step_fn._cache_size()
                assert cs == steady, (na, cs, steady)
        print("OK counts=" + str(counts) + " steady=" + str(steady))
    """)
    out = run_multidevice(code, n_devices=meshes.n_devices(mesh_name, gm),
                          timeout=560)
    assert "OK" in out


def test_non_elastic_step_rejects_active_mask():
    """Passing an active mask to a fixed-m step must be a loud error —
    the non-elastic graphs would silently ignore it."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.data.pipeline import LMWorkerPipeline
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()
        tcfg = TrainConfig(model=cfg, byzantine=ByzantineConfig(),
                           optimizer="sgd", agg_scope="global",
                           agg_layout="a2a")
        bundle = build_train_step(tcfg, mesh)
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, 8, 2, 32)
        batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                 for k, v in pipe.batch(0).items()}
        with mesh:
            try:
                bundle.step_fn(params, (), batch, jnp.int32(0), key,
                               jnp.ones(8, jnp.float32))
            except ValueError as e:
                assert "non-elastic" in str(e), e
                print("OK")
            else:
                raise AssertionError("active mask silently accepted")
    """)
    assert "OK" in run_multidevice(code, n_devices=8, timeout=560)


def test_build_step_validates_quorum_against_mesh():
    """max_m/quorum that disagree with the mesh's worker slots fail at
    build time, naming both numbers."""
    code = textwrap.dedent("""
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()
        for bad in (ByzantineConfig(max_m=6, quorum=4),
                    ByzantineConfig(quorum=12, max_m=16)):
            tcfg = TrainConfig(model=cfg, byzantine=bad, optimizer="sgd",
                               agg_scope="global", agg_layout="a2a")
            try:
                build_train_step(tcfg, mesh)
            except ValueError as e:
                assert "worker slots" in str(e), e
            else:
                raise AssertionError(f"accepted {bad}")
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8, timeout=560)


# ---------------------------------------------------------------------------
# lint: real elastic traces are clean under masked-psum-validity
# ---------------------------------------------------------------------------

def test_elastic_traces_clean_under_masked_psum_rule():
    """Both elastic scopes trace with zero masked-psum-validity
    violations (the seeded-broken counterpart lives in
    analysis.matrix.seeded_cases and is pinned by test_analysis /
    ``lint --selftest``)."""
    code = textwrap.dedent("""
        import dataclasses
        import jax
        from repro.analysis import jaxpr as ajaxpr, matrix
        from repro.analysis.rules import RuleContext, run_rules
        from repro.core import engine
        from repro.launch.mesh import worker_axes
        from repro.training.step import build_train_step

        for layout in ("a2a", "gather", "blocked"):
            tcfg = matrix.lint_train_config("brsgd", layout)
            bcfg = dataclasses.replace(tcfg.byzantine, max_m=8, quorum=6,
                                       alpha=0.25)
            tcfg = dataclasses.replace(tcfg, byzantine=bcfg)
            mesh = matrix.make_lint_mesh("flat")
            bundle = build_train_step(tcfg, mesh, jit=False)
            structs = matrix._step_structs(tcfg, bundle, mesh)
            act = jax.ShapeDtypeStruct((8,), jax.numpy.float32)
            contract = ajaxpr.extract(
                jax.make_jaxpr(bundle.step_fn)(*structs, act),
                meta={"ir": "jaxpr"})
            ctx = RuleContext(case=f"elastic/{layout}", aggregator="brsgd",
                              layout=layout, scope=bundle.scope,
                              mesh_name="flat", m=8,
                              spec=engine.get_spec("brsgd"), elastic=True,
                              worker_axes=tuple(worker_axes(mesh,
                                                            bundle.scope)))
            vs = run_rules(contract, ctx, rules=["masked-psum-validity"])
            assert not vs, [v.format() for v in vs]
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8, timeout=560)
