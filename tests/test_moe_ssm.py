"""MoE dispatch and SSM/RWKV recurrence correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoESpec, RWKVSpec, SSMSpec
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.params import init_params


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_setup(E=4, k=2, d=32, f=64, T=48, cap=100.0):
    spec = MoESpec(n_experts=E, top_k=k, d_ff_expert=f, capacity_factor=cap)
    p = init_params(MOE.moe_defs(d, spec), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.float32)
    return spec, p, x


def test_moe_lossless_capacity_matches_dense_oracle():
    spec, p, x = _moe_setup(cap=100.0)  # capacity >> E/k: nothing dropped
    out, aux = MOE.moe_ffn(p, x, spec)
    ref = MOE.ref_dense_moe(p, x, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert bool(jnp.isfinite(aux))


def test_moe_capacity_drops_tokens():
    spec, p, x = _moe_setup(cap=0.25)   # tight capacity: some drops
    out, _ = MOE.moe_ffn(p, x, spec)
    ref = MOE.ref_dense_moe(p, x, spec)
    # dropped tokens make out != ref, but out stays finite and bounded
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).max()) < 1e3


def test_moe_router_normalized_topk():
    spec, p, x = _moe_setup()
    w, ids, aux = MOE.route(p["router"], x, spec)
    assert w.shape == (x.shape[0], spec.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-4)
    assert int(ids.max()) < spec.n_experts


def test_moe_shared_experts_added():
    spec = MoESpec(n_experts=4, top_k=1, d_ff_expert=16, n_shared=2,
                   capacity_factor=100.0)
    p = init_params(MOE.moe_defs(8, spec), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 8), jnp.float32)
    out, _ = MOE.moe_ffn(p, x, spec)
    ref = MOE.ref_dense_moe(p, x, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_dispatch_indices_positions_within_capacity():
    spec = MoESpec(n_experts=3, top_k=1)
    ids = jnp.asarray([[0], [0], [0], [1], [2], [0]])
    w = jnp.ones((6, 1), jnp.float32)
    tok, cw, val, slot_of = MOE.dispatch_indices(ids, w, spec, cap=2)
    # expert 0 receives tokens 0,1 (2 = cap); tokens 2 and 5 dropped
    assert np.asarray(val)[0].sum() == 2
    assert set(np.asarray(tok)[0][np.asarray(val)[0]]) == {0, 1}
    # inverse map: dropped assignments point at the zero pad slot E*C
    so = np.asarray(slot_of).reshape(-1)
    assert so[2] == 3 * 2 and so[5] == 3 * 2          # dropped -> pad
    assert so[0] == 0 and so[1] == 1                  # expert0 slots 0,1
    assert so[3] == 1 * 2 + 0 and so[4] == 2 * 2 + 0  # experts 1,2 pos 0


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def _ssd_sequential(xh, dt, A, Bc, Cc):
    """O(S·N·P) reference recurrence."""
    Bsz, S, H, Pd = xh.shape
    N = Bc.shape[-1]
    S_state = np.zeros((Bsz, H, N, Pd), np.float64)
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t], np.float64) * np.asarray(A, np.float64))
        dBx = np.einsum("bn,bh,bhp->bhnp", np.asarray(Bc[:, t], np.float64),
                        np.asarray(dt[:, t], np.float64),
                        np.asarray(xh[:, t], np.float64))
        S_state = S_state * dA[:, :, None, None] + dBx
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cc[:, t], np.float64),
                            S_state))
    return np.stack(ys, 1), S_state


@pytest.mark.parametrize("S,chunk", [(16, 4), (20, 8), (7, 16), (32, 32)])
def test_ssd_chunked_matches_sequential(S, chunk):
    rng = np.random.default_rng(0)
    Bsz, H, Pd, N = 2, 3, 4, 5
    xh = jnp.asarray(rng.normal(size=(Bsz, S, H, Pd)).astype("f4"))
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(Bsz, S, H)).astype("f4"))
    A = jnp.asarray(rng.uniform(-1.0, -0.1, size=(H,)).astype("f4"))
    Bc = jnp.asarray(rng.normal(size=(Bsz, S, N)).astype("f4"))
    Cc = jnp.asarray(rng.normal(size=(Bsz, S, N)).astype("f4"))
    y, Sf = M2._ssd_chunked(xh, dt, A, Bc, Cc, chunk)
    y_ref, S_ref = _ssd_sequential(xh, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(Sf), S_ref, rtol=1e-3, atol=1e-3)


def test_mamba2_decode_matches_forward():
    spec = SSMSpec(state_dim=8, head_dim=8, chunk=4, conv_width=3)
    D, B, S = 16, 2, 10
    p = init_params(M2.mamba2_defs(D, spec), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    y_full, _ = M2.mamba2_forward(p, spec, x)
    di = spec.expand * D
    conv = jnp.zeros((B, spec.conv_width - 1, di + 2 * spec.state_dim))
    H = di // spec.head_dim
    ssm = jnp.zeros((B, H, spec.state_dim, di // H), jnp.float32)
    for t in range(S):
        y_t, (conv, ssm) = M2.mamba2_decode(p, spec, x[:, t:t + 1], conv, ssm)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_causal_conv_state_stitching():
    """Streaming conv over two halves == one-shot conv."""
    rng = np.random.default_rng(1)
    kern = jnp.asarray(rng.normal(size=(4, 6)).astype("f4"))
    bias = jnp.asarray(rng.normal(size=(6,)).astype("f4"))
    x = jnp.asarray(rng.normal(size=(2, 12, 6)).astype("f4"))
    full, _ = M2._causal_conv(x, kern, bias)
    h1, st = M2._causal_conv(x[:, :5], kern, bias)
    h2, _ = M2._causal_conv(x[:, 5:], kern, bias, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def test_rwkv_timemix_decode_matches_full():
    spec = RWKVSpec(head_dim=8, decay_lora=8, mix_lora=4)
    D, B, S = 16, 2, 9
    p = init_params(R6.rwkv6_defs(D, 32, spec), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    y_full, _ = R6.rwkv6_timemix(p, spec, x)
    last = None
    state = jnp.zeros((B, D // spec.head_dim, spec.head_dim, spec.head_dim),
                      jnp.float32)
    for t in range(S):
        y_t, (last, state) = R6.rwkv6_timemix(p, spec, x[:, t:t + 1],
                                              last_x=last, state=state)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_rwkv_decay_in_unit_interval():
    spec = RWKVSpec(head_dim=8, decay_lora=8, mix_lora=4)
    D = 16
    p = init_params(R6.rwkv6_defs(D, 32, spec), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, D), jnp.float32)
    dec = (p["decay_base"].astype(jnp.float32)
           + jnp.tanh(x @ p["decay_A"]) @ p["decay_B"])
    w = jnp.exp(-jnp.exp(dec))
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0


@pytest.mark.parametrize("S,Q,wlo", [(32, 8, 0.05), (35, 16, 0.05),
                                     (64, 64, 0.3), (128, 32, 0.2),
                                     (16, 4, 0.02)])
def test_wkv_chunked_matches_scan(S, Q, wlo):
    """Chunked-parallel WKV6 (§Perf) == per-token scan across chunk
    sizes, ragged tails, and decay regimes."""
    rng = np.random.default_rng(S * 100 + Q)
    B, H, K = 2, 3, 8
    mk = lambda: jnp.asarray(rng.normal(size=(B, S, H, K)).astype("f4"))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(wlo, 0.999, size=(B, S, H, K)).astype("f4"))
    u = jnp.asarray(rng.normal(size=(H, K)).astype("f4"))
    S0 = jnp.asarray(rng.normal(size=(B, H, K, K)).astype("f4"))
    y1, Sf1 = R6._wkv_scan(r, k, v, w, u, S0)
    y2, Sf2 = R6._wkv_chunked(r, k, v, w, u, S0, Q)
    scale = max(1.0, float(jnp.abs(y1).max()))
    np.testing.assert_allclose(np.asarray(y2) / scale, np.asarray(y1) / scale,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(Sf2), np.asarray(Sf1),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_timemix_chunked_config_matches():
    """rwkv6_timemix with spec.chunk>0 == chunk=0 on the same params."""
    import dataclasses
    spec0 = RWKVSpec(head_dim=8, decay_lora=8, mix_lora=4, chunk=0)
    spec1 = dataclasses.replace(spec0, chunk=8)
    D, B, S = 16, 2, 20
    p = init_params(R6.rwkv6_defs(D, 32, spec0), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    y0, (lx0, st0) = R6.rwkv6_timemix(p, spec0, x)
    y1, (lx1, st1) = R6.rwkv6_timemix(p, spec1, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st0),
                               rtol=1e-3, atol=1e-4)


def test_wkv_scan_state_accumulates():
    """With decay w=1 and r=e_j the scan output reproduces cumulative
    k·v sums (hand-checkable recurrence)."""
    B, S, H, K = 1, 4, 1, 3
    r = jnp.tile(jnp.eye(K)[0][None, None, None], (B, S, H, 1))
    k = jnp.ones((B, S, H, K))
    v = jnp.cumsum(jnp.ones((B, S, H, K)), axis=1)   # 1,2,3,4
    w = jnp.ones((B, S, H, K))
    u = jnp.zeros((H, K))
    y, Sf = R6._wkv_scan(r, k, v, w, u, jnp.zeros((B, H, K, K)))
    # y_t = r·S_t where S_t = sum_{s<t} k_s v_s^T  -> column sums 0,1,3,6
    np.testing.assert_allclose(np.asarray(y[0, :, 0, 0]),
                               np.asarray([0.0, 1.0, 3.0, 6.0]), atol=1e-5)
