"""Checkpoint layer tests: atomic save protocol, torn-write
resilience, manifest key validation, bit-exact sharded round-trips on
both mesh families, and the hot-swap-under-decode guarantees (no
recompile, no stale-param token).
"""
import os
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import meshes
from conftest import run_multidevice
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models import params as PM
from repro.models import transformer as TF
from repro.serving import HotSwapper, ServeLoop


def test_roundtrip_bitexact(tmp_path):
    d = str(tmp_path)
    tree = {"w": {"a": np.arange(32, dtype=np.float32).reshape(4, 8),
                  "b": jnp.asarray(np.linspace(-1, 1, 8), jnp.bfloat16)},
            "s": np.int32(7)}
    ckpt.save(d, tree, step=3)
    got, step = ckpt.restore(d, like=tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # atomic protocol leaves no temp droppings
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_latest_step_and_torn_write(tmp_path):
    """A crash between the .npz and the manifest (torn write) leaves the
    step invisible; a manifest/npz disagreement fails loudly."""
    d = str(tmp_path)
    tree = {"a": np.ones((3,), np.float32)}
    ckpt.save(d, tree, step=1)
    ckpt.save(d, tree, step=2)
    assert ckpt.latest_step(d) == 2
    # simulate the crash: step 3's npz landed, manifest did not
    ckpt.save(d, tree, step=3)
    os.remove(os.path.join(d, "step_00000003.json"))
    assert ckpt.latest_step(d) == 2          # orphan npz is invisible
    got, step = ckpt.restore(d, like=tree)
    assert step == 2 and np.allclose(np.asarray(got["a"]), 1.0)
    # manifest that lies about its npz contents -> "torn write?" error
    ckpt._atomic_write(
        os.path.join(d, "step_00000004.npz"),
        lambda tmp: np.savez(ckpt.tmp_npz(tmp), a=np.ones((3,), np.float32)))
    ckpt._atomic_write(
        os.path.join(d, "step_00000004.json"),
        lambda tmp: ckpt._dump_json(tmp, {"step": 4, "keys": ["a", "ghost"],
                                          "extra": {}}))
    with pytest.raises(ValueError, match="torn write"):
        ckpt.restore(d, like={"a": np.ones((3,), np.float32),
                              "ghost": np.ones((2,), np.float32)}, step=4)


def test_restore_validates_manifest_keys(tmp_path):
    """A checkpoint from a different model fails with the missing/extra
    key names, before any array is loaded."""
    d = str(tmp_path)
    ckpt.save(d, {"w": {"a": np.ones((2,), np.float32),
                        "old_name": np.ones((2,), np.float32)}}, step=1)
    like = {"w": {"a": np.ones((2,), np.float32),
                  "new_name": np.ones((2,), np.float32)}}
    with pytest.raises(ValueError) as e:
        ckpt.restore(d, like=like)
    msg = str(e.value)
    assert "missing=['w/new_name']" in msg
    assert "extra=['w/old_name']" in msg
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(d, like={"w": {"a": np.ones((3,), np.float32),
                                    "old_name": np.ones((2,), np.float32)}})


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_roundtrip_sharded_mesh_matrix(mesh_name, tmp_path):
    """save → restore(shardings=...) is bit-exact and lands on the
    requested shardings, on both mesh families (flat worker-only and
    data×model tensor-parallel)."""
    code = meshes.preamble(mesh_name, 4) + textwrap.dedent(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.compat import P
        from repro.checkpoint import ckpt

        d = {str(tmp_path)!r}
        rng = np.random.default_rng(0)
        tree = {{"emb": rng.normal(size=(8, 16)).astype(np.float32),
                 "mlp": rng.normal(size=(4, 8)).astype(np.float32),
                 "bias": rng.normal(size=(16,)).astype(np.float32)}}
        maxis = MAXES[0] if MAXES else None
        sh = {{"emb": NamedSharding(mesh, P(wspec, maxis)),
              "mlp": NamedSharding(mesh, P(None, wspec)),
              "bias": NamedSharding(mesh, P(maxis))}}
        placed = {{k: jax.device_put(jnp.asarray(v), sh[k])
                  for k, v in tree.items()}}
        ckpt.save(d, placed, step=5)
        got, step = ckpt.restore(d, like=placed, shardings=sh)
        assert step == 5
        for k in tree:
            assert got[k].sharding == sh[k], (k, got[k].sharding)
            np.testing.assert_array_equal(np.asarray(got[k]), tree[k])
        print("OK")
    """)
    assert "OK" in run_multidevice(
        code, n_devices=meshes.n_devices(mesh_name, 4))


def test_hot_swap_under_decode(tmp_path, rng):
    """Swap while a request is mid-decode: zero decode recompiles and
    no stale-param token — every post-swap token matches a reference
    decode that switches params at the same step, and the stream
    diverges from the never-swapped reference (the swap really landed).
    """
    cfg = get_config("qwen3-0.6b").reduced()
    params_old = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    params_new = jax.tree.map(lambda x: -x, params_old)
    d = str(tmp_path)
    ckpt.save(d, params_old, step=1)

    prompt = rng.integers(0, cfg.vocab, size=6)
    gen, max_len = 10, 24
    swap_at = 4                              # publish after decode step 4

    swapper = HotSwapper(d, like=params_old)
    loop = ServeLoop(cfg, max_batch=1, max_len=max_len, swapper=swapper)
    rid = loop.submit(prompt, gen)

    def on_step(lp, s):
        if s == swap_at:
            ckpt.save(d, params_new, step=2)

    got = loop.run(on_step=on_step)[rid]
    assert swapper.swap_count == 1 and swapper.loaded_step == 2
    assert loop.decode_compiles() == 1, "decode recompiled across the swap"
    assert len(got) == gen

    def reference(swap_step):
        """Greedy decode switching params after ``swap_step`` decode
        steps (None = never), sharing the cache across the switch."""
        dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        cache = TF.init_cache(cfg, 1, max_len, dtype)
        logits, cache = TF.prefill_cache(cfg, params_old,
                                         jnp.asarray(prompt[None]), cache)
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        toks, pos = [int(tok)], len(prompt)
        for i in range(gen - 1):
            p = params_old if swap_step is None or i < swap_step else params_new
            logits, cache = TF.decode_step(cfg, p, cache,
                                           tok[None, None], jnp.int32(pos))
            tok = jnp.argmax(logits[0, 0]).astype(jnp.int32)
            toks.append(int(tok))
            pos += 1
        return np.asarray(toks, np.int32)

    # the loop polls at the top of each iteration, so the swap published
    # after decode step `swap_at` takes effect from decode step swap_at+1
    np.testing.assert_array_equal(got, reference(swap_at),
                                  err_msg="stale-param token after swap")
    assert not np.array_equal(got, reference(None)), \
        "stream identical to the never-swapped reference — swap had no effect"
