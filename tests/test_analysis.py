"""Static-analysis layer: contract extraction, rule firing, jaxpr↔HLO
agreement, and the BENCH_contracts.json schema guard.

The contract/rule machinery is pure tracing, but collectives only exist
inside shard_map over a real mesh, so the extraction tests run in
``run_multidevice`` subprocesses (8 host devices — the lint meshes),
like every other distributed suite.  The legacy ad-hoc jaxpr-walker
pins (tests/test_engine.py gather-count, tests/test_blocked.py
barrier-gather) are migrated ONTO this API — ``repro.analysis.jaxpr``
is the single jaxpr-walking implementation in the repo.
"""
import importlib.util
import json
import pathlib
import textwrap

import pytest

from conftest import run_multidevice

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# contract extraction (known counts / bytes / context)
# ---------------------------------------------------------------------------

def test_extract_counts_bytes_and_manual_context():
    """One all_gather + a scanned psum + one all_to_all, hand-built:
    the walker must report exact counts, payload bytes, the scan trip
    multiplier, manual-axis context and a file:line source."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from functools import partial
        from repro.compat import P, shard_map
        from repro.launch.mesh import make_mesh
        from repro.analysis import trace

        m = 8
        mesh = make_mesh((m,), ("data",))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"),), out_specs=P())
        def f(g):
            g = g.reshape(g.shape[1:])                  # [24] f32
            G = jax.lax.all_gather(g, ("data",))        # [8, 24]
            Gc = jax.lax.all_to_all(g.reshape(m, 3), ("data",),
                                    split_axis=0, concat_axis=0)
            def body(c, _):
                return c + jax.lax.psum(jnp.sum(G), ("data",)), None
            c, _ = jax.lax.scan(body, jnp.float32(0), None, length=5)
            return c + jnp.sum(Gc)

        x = jax.ShapeDtypeStruct((m, 24), jnp.float32)
        c = trace(f, x)
        assert c.count("all_gather") == 1, c.summary()
        assert c.count("all_to_all") == 1, c.summary()
        assert c.count("all_reduce") == 5, c.summary()   # scan ×5
        assert c.total_bytes("all_gather") == 8 * 24 * 4
        assert c.total_bytes("all_reduce") == 5 * 4
        (ag,) = c.of_kind("all_gather")
        assert ag.axes == ("data",) and ag.manual_axes == ("data",)
        assert ag.in_shard_map and not ag.auto_axes
        assert ag.source, "source_info missing"
        assert ag.dtype == "float32" and ag.shape == (8, 24)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8)


def test_extract_recurses_custom_vjp_and_pjit():
    """Collectives inside a custom_vjp backward (the blocked barrier
    mechanism) and under an inner jit are still found."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from functools import partial
        from repro.compat import P, shard_map
        from repro.launch.mesh import make_mesh
        from repro.analysis import trace

        mesh = make_mesh((8,), ("data",))

        @jax.custom_vjp
        def bar(x):
            return x
        def fwd(x):
            return x, None
        def bwd(res, ct):
            return (jax.lax.psum(ct, ("data",)),)
        bar.defvjp(fwd, bwd)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"),), out_specs=P())
        def f(g):
            g = g.reshape(g.shape[1:])
            inner = jax.jit(lambda v: jax.lax.all_gather(v, ("data",)))
            loss = lambda v: jnp.sum(bar(v)) + jnp.sum(inner(v))
            return jax.grad(loss)(g)[0]

        c = trace(f, jax.ShapeDtypeStruct((8, 6), jnp.float32))
        assert c.count("all_gather") >= 1, c.summary()
        assert c.count("all_reduce") >= 1, c.summary()
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8)


# ---------------------------------------------------------------------------
# every shipped rule fires on its seeded broken toy
# ---------------------------------------------------------------------------

def test_seeded_violations_fire_with_detail():
    """matrix.run_selftest: each deliberately-broken toy (double
    gather, bf16 stats psum, partial-manual gather, worker-matrix
    gather, 1-byte budget) trips exactly its rule; violations carry
    rule/file/collective detail."""
    code = textwrap.dedent("""
        from repro.analysis import matrix
        from repro.analysis.rules import run_rules

        failures = matrix.run_selftest(("flat", "dm"))
        assert not failures, failures

        rule, contract, ctx = matrix.seeded_cases(("flat",))[0]
        (v, *_) = run_rules(contract, ctx, rules=[rule])
        txt = v.format()
        assert "one-gather-per-leaf" in txt
        assert "all_gather" in txt
        assert ".py:" in txt, txt          # file:line of the bad gather
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8)


def test_clean_real_case_and_rule_registry():
    """A real traced case (brsgd/gather/flat) passes every rule; the
    registry surface mirrors the AggregatorSpec idiom."""
    code = textwrap.dedent("""
        from repro.analysis import matrix, rules

        assert set(rules.registered()) >= {
            "no-worker-gather-in-blocked-bwd", "one-gather-per-leaf",
            "no-collective-over-auto-axis", "psum-stats-dtype",
            "bytes-budget"}
        contract, ctx = matrix.trace_case("brsgd", "gather", "flat")
        vs = rules.run_rules(contract, ctx)
        assert not vs, [v.format() for v in vs]
        assert contract.count("all_gather") == ctx.n_leaves
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8)


# ---------------------------------------------------------------------------
# jaxpr ↔ HLO contract agreement on one real step
# ---------------------------------------------------------------------------

def test_jaxpr_hlo_agreement_brsgd_gather_flat():
    """The trace-time contract and the lowered (unoptimized, pre-SPMD)
    HLO contract of the SAME (brsgd, gather, flat) train step must
    agree: identical per-kind collective counts, payload bytes within
    2%.  Pre-SPMD HLO is the honest comparison point — GSPMD has not
    yet added auto-region collectives and no combiner pass has merged
    manual-region ones."""
    code = textwrap.dedent("""
        import jax
        from repro.analysis import hlo as ahlo
        from repro.analysis import matrix
        from repro.training.step import build_train_step

        cj, ctx = matrix.trace_case("brsgd", "gather", "flat")

        tcfg = matrix.lint_train_config("brsgd", "gather")
        mesh = matrix.make_lint_mesh("flat")
        bundle = build_train_step(tcfg, mesh)
        structs = matrix._step_structs(tcfg, bundle, mesh)
        lowered = bundle.step_fn.lower(*structs)
        ch = ahlo.extract(ahlo.lower_to_hlo_text(lowered))

        for kind in ("all_gather", "all_to_all", "all_reduce"):
            assert cj.count(kind) == ch.count(kind), (
                kind, cj.summary(), ch.summary())
        for kind in ("all_gather", "all_reduce"):
            j, h = cj.total_bytes(kind), ch.total_bytes(kind)
            assert abs(j - h) <= 0.02 * max(j, h), (kind, j, h)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=8)


# ---------------------------------------------------------------------------
# BENCH_contracts.json schema guard (in-process, no devices)
# ---------------------------------------------------------------------------

def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", REPO / "benchmarks" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_committed_contracts_file_is_valid():
    cb = _load_check_bench()
    errors = cb.check_contracts(str(REPO / "BENCH_contracts.json"))
    assert not errors, errors


def test_contracts_checker_rejects_unknown_names(tmp_path):
    cb = _load_check_bench()
    data = json.loads((REPO / "BENCH_contracts.json").read_text())
    data["cases"][0]["aggregator"] = "definitely-not-registered"
    data["cases"][1]["layout"] = "teleport"
    bad = tmp_path / "BENCH_contracts.json"
    bad.write_text(json.dumps(data))
    errors = cb.check_contracts(str(bad))
    assert any("unknown aggregator" in e for e in errors), errors
    assert any("unknown layout" in e for e in errors), errors


def test_contracts_checker_requires_full_coverage(tmp_path):
    cb = _load_check_bench()
    data = json.loads((REPO / "BENCH_contracts.json").read_text())
    data["cases"] = [c for c in data["cases"]
                     if not (c["aggregator"] == "brsgd"
                             and c["layout"] == "blocked")]
    bad = tmp_path / "BENCH_contracts.json"
    bad.write_text(json.dumps(data))
    errors = cb.check_contracts(str(bad))
    assert any("coverage" in e for e in errors), errors
