"""FaultSpec registry, ChaosPlan schedules, checkpoint retention /
last_good, HotSwapper quarantine, and the recovery supervisor's state
machine (DESIGN.md §Faults).

The supervisor tests drive a FAKE host-side step function so the full
policy (eviction, probation re-admission, quorum shrink/hold, bounded
rollback with backoff) runs in milliseconds; the real guarded compiled
step is covered by ``test_guarded_step_holds_and_recovers`` on an
8-device subprocess and end-to-end by ``benchmarks/chaos.py`` in CI.
"""
from __future__ import annotations

import textwrap

import numpy as np
import pytest

from conftest import run_multidevice
from repro.checkpoint import ckpt
from repro.configs import ByzantineConfig, RecoveryConfig
from repro.faults import (ChaosPlan, FaultEvent, FaultSpec, Supervisor,
                          SupervisorError, Trigger, feasible_round,
                          get_spec, registered)

SHIPPED = ("corrupt_ckpt", "flap", "host_crash", "nan_burst",
           "slot_stall", "stale_swap", "torn_ckpt")


# ---------------------------------------------------------------------------
# registry + triggers
# ---------------------------------------------------------------------------

def test_registry_ships_the_taxonomy():
    assert set(SHIPPED) <= set(registered())
    with pytest.raises(KeyError, match="registered"):
        get_spec("nope")
    with pytest.raises(ValueError, match="scope"):
        FaultSpec("x", "disk", lambda: None)
    with pytest.raises(ValueError, match="permanent"):
        FaultSpec("x", "grad", lambda: None, permanent=True)


def test_trigger_schedules():
    rng = np.random.default_rng(0)
    # one-shot with duration
    s = Trigger(at=3, duration=2).schedule(8, rng)
    np.testing.assert_array_equal(s, [0, 0, 0, 1, 1, 0, 0, 0])
    # periodic
    s = Trigger(at=1, every=3).schedule(8, rng)
    np.testing.assert_array_equal(s, [0, 1, 0, 0, 1, 0, 0, 1])
    # bernoulli draws are seeded => reproducible, and never before `at`
    a = Trigger(at=4, prob=0.5).schedule(64, np.random.default_rng(7))
    b = Trigger(at=4, prob=0.5).schedule(64, np.random.default_rng(7))
    np.testing.assert_array_equal(a, b)
    assert not a[:4].any() and a.any()
    with pytest.raises(ValueError, match="duration"):
        Trigger(duration=0)
    with pytest.raises(ValueError, match="prob"):
        Trigger(prob=1.5)


def test_chaos_plan_masks_crash_vs_flap():
    """host_crash latches (permanent); flap rejoins after duration."""
    plan = ChaosPlan([
        FaultEvent("host_crash", Trigger(at=2), workers=(6,)),
        FaultEvent("flap", Trigger(at=3, duration=2), workers=(4,)),
        FaultEvent("nan_burst", Trigger(at=5), workers=(1,)),
    ], m=8, n_steps=10)
    expect_gone = {2: {6}, 3: {6, 4}, 4: {6, 4}, 5: {6}, 9: {6}}
    for step, gone in expect_gone.items():
        mask = plan.worker_mask(step)
        assert set(np.flatnonzero(mask == 0)) == gone, step
    assert plan.grad_faults(4).sum() == 0
    np.testing.assert_array_equal(np.flatnonzero(plan.grad_faults(5)), [1])
    # edges: flap fires once at 3 (not again at 4)
    assert [ev.fault for ev, _ in plan.fired(3)] == ["flap"]
    assert plan.fired(4) == []
    # drawn targets are recorded back onto the events + describe() rows
    plan2 = ChaosPlan([FaultEvent("nan_burst", Trigger(at=0), n=2)],
                      m=8, n_steps=4, seed=3)
    assert len(plan2.events[0].workers) == 2
    rows = plan2.describe()
    assert rows[0]["fault"] == "nan_burst" and rows[0]["at"] == 0
    assert rows[0]["workers"] == list(plan2.events[0].workers)


# ---------------------------------------------------------------------------
# checkpoint retention + last_good + validation
# ---------------------------------------------------------------------------

def _tree(x):
    return {"w": np.full((4, 3), x, np.float32), "b": np.arange(3.0)}


def test_keep_last_k_spares_last_good(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        ckpt.save(d, _tree(s), step=s, keep=2)
        if s == 2:
            ckpt.mark_good(d, 2)
    # keep=2 would leave {4, 5}; last_good=2 survives regardless of age
    assert ckpt.steps(d) == [2, 4, 5]
    assert ckpt.last_good_step(d) == 2


def test_mark_good_refuses_corruption(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, _tree(1), step=1)
    ckpt.mark_good(d, 1, like=_tree(0))
    ckpt.save(d, _tree(2), step=2)
    get_spec("corrupt_ckpt").inject(d, 2, np.random.default_rng(0))
    with pytest.raises(ValueError, match="disagree"):
        ckpt.mark_good(d, 2)
    assert ckpt.last_good_step(d) == 1      # pointer did not move
    ckpt.save(d, _tree(3), step=3)
    get_spec("torn_ckpt").inject(d, 3, np.random.default_rng(0))
    with pytest.raises(Exception):          # zlib/zip error on truncation
        ckpt.validate(d, 3)


def test_hot_swapper_quarantines_bad_publish(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.serving.swap import HotSwapper
    d = str(tmp_path)
    like = _tree(0)
    ckpt.save(d, _tree(1), step=1)
    sw = HotSwapper(d, like=like)
    assert sw.loaded_step == 1
    ckpt.save(d, _tree(2), step=2)
    get_spec("corrupt_ckpt").inject(d, 2, np.random.default_rng(0))
    assert not sw.poll()                    # bad publish: kept serving 1
    assert sw.loaded_step == 1 and 2 in sw.quarantined
    ckpt.save(d, _tree(3), step=3)
    assert sw.poll()                        # newer good ckpt still lands
    assert sw.loaded_step == 3
    assert not sw.poll()                    # quarantined step never retried
    np.testing.assert_array_equal(np.asarray(sw.params()["w"]),
                                  _tree(3)["w"])


# ---------------------------------------------------------------------------
# supervisor state machine (fake host-side step)
# ---------------------------------------------------------------------------

BCFG = ByzantineConfig(alpha=0.25, max_m=8, quorum=6)


class FakeStep:
    """Mimics the guarded step's contract: held when any active worker
    is faulted, per-worker finiteness in ``worker_ok``."""

    def __init__(self, m=8):
        self.m = m
        self.calls = 0

    def __call__(self, params, opt_state, batch, step, key, act, flt, ema):
        self.calls += 1
        act, flt = np.asarray(act), np.asarray(flt)
        bad = (flt > 0) & (act > 0)
        ok = not bad.any()
        met = {"loss": 1.0 if ok else float("nan"), "ce": 1.0,
               "gnorm": 1.0 if ok else float("nan"),
               "n_selected": act.sum(), "n_selected_min": act.sum(),
               "n_active": act.sum(),
               "worker_ok": 1.0 - bad.astype(np.float32),
               "step_ok": float(ok), "grad_finite": float(ok),
               "loss_spike": 0.0}
        return (params if not ok else params + 1), opt_state, met


def test_supervisor_evicts_and_readmits():
    rcfg = RecoveryConfig(guard=True, evict_after=1, readmit_after=3)
    sup = Supervisor(FakeStep(), BCFG, rcfg, 8)
    flt = np.zeros(8, np.float32)
    flt[5] = 1
    p, _, met = sup.run_step(0.0, (), None, 0, None, faults=flt)
    assert met["held"] == "nonfinite" and p == 0.0
    assert sup.evicted[5] and sup.evictions == 1
    # evicted worker is masked out -> healthy even though still faulted
    p, _, met = sup.run_step(p, (), None, 1, None, faults=flt)
    assert "held" not in met and p == 1.0
    assert met["n_active"] == 7.0
    # probation re-admission after readmit_after steps (fault cleared)
    p, _, met = sup.run_step(p, (), None, 4, None)
    assert not sup.evicted[5] and sup.readmissions == 1
    assert met["n_active"] == 8.0


def test_supervisor_quorum_shrink_and_hold():
    # alpha=0.5 makes the bound falsifiable below quorum: feasible iff
    # n_active > 2*floor(n_active/2), i.e. iff n_active is odd.  (At
    # alpha=0.25 every n_active >= 1 passes — shrink always runs.)
    bcfg = ByzantineConfig(alpha=0.5, max_m=8, quorum=7)
    rcfg = RecoveryConfig(guard=True)
    sup = Supervisor(FakeStep(), bcfg, rcfg, 8)
    # 5 < quorum=7 but 5 > 2*floor(.5*5)=4: shrink and run
    act = np.ones(8, np.float32)
    act[:3] = 0
    p, _, met = sup.run_step(0.0, (), None, 0, None, sched_active=act)
    assert "held" not in met and sup.quorum_shrinks == 1
    assert met["n_active"] == 5.0
    # 2 active fails the honest-majority bound (2 <= 2*floor(1)): hold,
    # the step never runs
    fake = sup.step_fn
    calls = fake.calls
    act = np.zeros(8, np.float32)
    act[:2] = 1
    p, _, met = sup.run_step(p, (), None, 1, None, sched_active=act)
    assert met["held"] == "quorum" and fake.calls == calls
    assert sup.quorum_holds == 1 and np.isnan(met["loss"])
    assert feasible_round(5, 0.5) and not feasible_round(2, 0.5)


def test_supervisor_rollback_backoff_and_budget(tmp_path):
    d = str(tmp_path)
    rcfg = RecoveryConfig(guard=True, evict_after=99, rollback_after=2,
                          max_rollbacks=2, backoff_base=2, keep_ckpts=4)
    like = _tree(0)
    sup = Supervisor(FakeStep(), BCFG, rcfg, 8, ckpt_dir=d, like=like)
    sup.checkpoint(_tree(7), 1)
    assert ckpt.last_good_step(d) == 1
    flt = np.zeros(8, np.float32)
    flt[3] = 1
    p = like
    # two consecutive held steps -> rollback #1 restores last_good
    p, _, met = sup.run_step(p, (), None, 0, None, faults=flt)
    assert sup.rollbacks == 0
    p, _, met = sup.run_step(p, (), None, 1, None, faults=flt)
    assert sup.rollbacks == 1
    np.testing.assert_array_equal(p["w"], _tree(7)["w"])
    # cooldown: held steps during backoff don't re-roll
    p, _, met = sup.run_step(p, (), None, 2, None, faults=flt)
    assert sup.rollbacks == 1
    # past cooldown (step >= 1 + 2*2^0 = 3): two more bad -> rollback #2
    p, _, met = sup.run_step(p, (), None, 3, None, faults=flt)
    p, _, met = sup.run_step(p, (), None, 4, None, faults=flt)
    assert sup.rollbacks == 2
    # budget exhausted -> SupervisorError, not a crash loop
    with pytest.raises(SupervisorError, match="budget"):
        for s in range(7, 20):
            p, _, met = sup.run_step(p, (), None, s, None, faults=flt)


def test_supervisor_rollback_skips_corrupt_last_good(tmp_path):
    d = str(tmp_path)
    rcfg = RecoveryConfig(guard=True, evict_after=99, rollback_after=1,
                          keep_ckpts=4)
    like = _tree(0)
    sup = Supervisor(FakeStep(), BCFG, rcfg, 8, ckpt_dir=d, like=like)
    sup.checkpoint(_tree(5), 1)
    sup.checkpoint(_tree(6), 2)           # last_good -> 2
    get_spec("corrupt_ckpt").inject(d, 2, np.random.default_rng(0))
    flt = np.zeros(8, np.float32)
    flt[3] = 1
    p, _, _ = sup.run_step(like, (), None, 0, None, faults=flt)
    # corrupt last_good skipped, older good anchor restored
    assert sup.rollbacks == 1
    np.testing.assert_array_equal(p["w"], _tree(5)["w"])
    assert any(e["kind"] == "rollback_skip" for e in sup.events)


def test_supervisor_requires_elastic():
    with pytest.raises(ValueError, match="elastic"):
        Supervisor(FakeStep(), ByzantineConfig(), RecoveryConfig(), 8)


# ---------------------------------------------------------------------------
# the real guarded compiled step (8-device subprocess)
# ---------------------------------------------------------------------------

def test_guarded_step_holds_and_recovers():
    """NaN burst on an honest worker: the step holds params on-device
    and reports the culprit; evicting it recovers — all with zero
    recompiles (active/faults/ema are traced)."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import (ARCHS, TrainConfig, ByzantineConfig,
                                   RecoveryConfig)
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.data.pipeline import LMWorkerPipeline
        from repro.launch.mesh import make_mesh, n_workers

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="sign_flip",
                               alpha=0.25, membership="prefix",
                               max_m=8, quorum=6)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope="global", agg_layout="a2a",
                           recovery=RecoveryConfig(guard=True))
        bundle = build_train_step(tcfg, mesh)
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)

        def one(s, act, flt, ema, params):
            batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                     for k, v in pipe.batch(s).items()}
            params, _, met = bundle.step_fn(
                params, (), batch, jnp.int32(s), jax.random.fold_in(key, s),
                jnp.asarray(act, jnp.float32), jnp.asarray(flt, jnp.float32),
                np.float32(ema))
            jax.block_until_ready(met["loss"])
            return params, {k: np.asarray(v) for k, v in met.items()}

        ones, zeros = np.ones(8, np.float32), np.zeros(8, np.float32)
        with mesh:
            for s in range(2):
                params, met = one(s, ones, zeros, -1.0, params)
            steady = bundle.step_fn._cache_size()
            clean = float(met["loss"])
            assert met["step_ok"] == 1.0 and met["worker_ok"].sum() == 8

            flt = zeros.copy(); flt[5] = 1
            before = np.asarray(jax.tree.leaves(params)[0])
            params, met = one(2, ones, flt, clean, params)
            assert met["step_ok"] == 0.0 and met["grad_finite"] == 0.0
            assert met["worker_ok"][5] == 0 and met["worker_ok"].sum() == 7
            assert np.isfinite(met["loss"])     # masked mean stays finite
            np.testing.assert_array_equal(
                before, np.asarray(jax.tree.leaves(params)[0]))

            act = ones.copy(); act[5] = 0       # evict: recovers
            params, met = one(3, act, flt, clean, params)
            assert met["step_ok"] == 1.0 and met["n_active"] == 7
            assert np.isfinite(met["loss"])
            assert bundle.step_fn._cache_size() == steady
        print("OK steady=" + str(steady))
    """)
    assert "OK" in run_multidevice(code, n_devices=8, timeout=560)
