import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "mesh_matrix: parity tests parametrized over tests/meshes.py — "
        "CI runs `-m mesh_matrix` with REPRO_TEST_MESHES=dm so the "
        "data×model job skips everything the worker-only job covers")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with n host devices.

    The main test process keeps the real single device (per the repo
    policy); shard_map/distribution tests get their own interpreter."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice snippet failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout
