"""Substrate tests: optimizers, checkpointing, data pipeline, configs,
hlo accounting, serving cache specs."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, ByzantineConfig, TrainConfig, get_config
from repro.checkpoint import ckpt
from repro.data.pipeline import ImageWorkerPipeline, LMWorkerPipeline
from repro.models import params as PM
from repro.models import transformer as TF
from repro.optim import get_optimizer


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _tcfg(opt, **kw):
    return TrainConfig(model=ARCHS["qwen3-0.6b"].reduced(), optimizer=opt, **kw)


def test_sgd_update_math():
    opt = get_optimizer(_tcfg("sgd", lr=0.1, grad_clip=0.0))
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    new, _ = opt.update(g, opt.init(p), p, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(new["w"]), [0.95, 2.1], rtol=1e-6)


def test_momentum_accumulates():
    opt = get_optimizer(_tcfg("momentum", lr=1.0, momentum=0.5, grad_clip=0.0))
    p = {"w": jnp.zeros(2)}
    g = {"w": jnp.ones(2)}
    st = opt.init(p)
    p, st = opt.update(g, st, p, jnp.int32(0))   # v=1, p=-1
    p, st = opt.update(g, st, p, jnp.int32(1))   # v=1.5, p=-2.5
    np.testing.assert_allclose(np.asarray(p["w"]), [-2.5, -2.5], rtol=1e-6)


def test_adamw_first_step_is_lr_sized():
    opt = get_optimizer(_tcfg("adamw", lr=1e-2, weight_decay=0.0, grad_clip=0.0))
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    new, st = opt.update(g, opt.init(p), p, jnp.int32(0))
    # bias-corrected first Adam step = -lr * sign(g) (+eps effects)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [-1e-2, 1e-2, -1e-2], rtol=1e-3)
    assert st["m"]["w"].dtype == jnp.float32


def test_grad_clip_global_norm():
    from repro.optim.optimizers import clip_by_global_norm
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    c = clip_by_global_norm(g, 1.0)   # norm 5 -> scale 0.2
    np.testing.assert_allclose(np.asarray(c["a"]), [0.6], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c["b"]), [0.8], rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), params, step=7, extra={"note": "t"})
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_missing(tmp_path):
    assert ckpt.latest_step(str(tmp_path / "nope")) is None
    t = {"w": jnp.ones(3)}
    ckpt.save(str(tmp_path), t, step=1)
    ckpt.save(str(tmp_path), t, step=5)
    assert ckpt.latest_step(str(tmp_path)) == 5
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), t)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), {"w": jnp.ones(3)}, step=0)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.ones(4)})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_pipeline_shapes_and_determinism():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    pipe = LMWorkerPipeline(cfg, n_workers=4, batch_per_worker=3, seq_len=16)
    b1, b2 = pipe.batch(0), pipe.batch(0)
    assert b1["tokens"].shape == (4, 3, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (pipe.batch(1)["tokens"] != b1["tokens"]).any()
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab


def test_lm_pipeline_label_flip_hits_byzantine_workers_only():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    byz = ByzantineConfig(attack="label_flip", alpha=0.5)
    clean = LMWorkerPipeline(cfg, 4, 2, 8, byz=None).batch(0)["tokens"]
    flip = LMWorkerPipeline(cfg, 4, 2, 8, byz=byz).batch(0)["tokens"]
    np.testing.assert_array_equal(flip[2:], clean[2:])
    np.testing.assert_array_equal(flip[:2], cfg.vocab - 1 - clean[:2])


def test_vlm_pipeline_provides_prefix_embed():
    cfg = ARCHS["phi-3-vision-4.2b"].reduced()
    pipe = LMWorkerPipeline(cfg, 2, 2, 8)
    b = pipe.batch(0)
    assert b["prefix_embed"].shape == (2, 2, cfg.n_prefix_tokens, cfg.d_model)


def test_image_pipeline_splits_and_flips():
    byz = ByzantineConfig(attack="label_flip", alpha=0.25)
    pipe = ImageWorkerPipeline(n_workers=4, n_per_worker=32, byz=byz)
    b = pipe.batch(0, batch_per_worker=8)
    assert b["images"].shape[:2] == (4, 8)
    assert b["labels"].min() >= 0 and b["labels"].max() <= 9


# ---------------------------------------------------------------------------
# configs / registry
# ---------------------------------------------------------------------------

def test_registry_has_all_assigned():
    assert set(ARCHS) == {
        "deepseek-v2-236b", "phi-3-vision-4.2b", "nemotron-4-15b",
        "musicgen-large", "minicpm3-4b", "dbrx-132b", "zamba2-2.7b",
        "qwen3-0.6b", "qwen3-1.7b", "rwkv6-7b"}
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}


def test_assigned_config_dims_match_spec():
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.vocab) == (60, 5120, 102400)
    assert c.moe.n_experts == 160 and c.moe.top_k == 6 and c.moe.n_shared == 2
    assert c.attention.kind == "mla" and c.attention.kv_lora_rank == 512
    c = get_config("nemotron-4-15b")
    assert (c.d_model, c.d_ff, c.vocab) == (6144, 24576, 256000)
    assert c.activation == "relu2" and c.attention.n_kv_heads == 8
    c = get_config("dbrx-132b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 4
    c = get_config("zamba2-2.7b")
    assert c.hybrid_attn_every > 0 and c.ssm is not None
    c = get_config("rwkv6-7b")
    assert c.attention.kind == "none" and c.rwkv is not None
    c = get_config("qwen3-0.6b")
    assert c.attention.qk_norm and c.attention.n_kv_heads == 8


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("gpt-99")


def test_shapes_registry_values():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].mode == "decode"


# ---------------------------------------------------------------------------
# hlo accounting
# ---------------------------------------------------------------------------

def test_module_stats_scan_trip_multiplication():
    from repro.launch.hlo_stats import module_stats

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(s, s).compile().as_text()
    st = module_stats(txt)
    want = 10 * 2 * 64 ** 3
    assert want <= st["flops"] <= 1.2 * want
    assert st["unknown_trip_whiles"] == 0
    assert st["bytes"] >= 10 * 2 * 64 * 64 * 4   # >= in+out per iteration


def test_module_stats_counts_plain_dot():
    from repro.launch.hlo_stats import module_stats
    s = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 16), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(s, w).compile().as_text()
    st = module_stats(txt)
    want = 2 * 32 * 128 * 16
    assert want <= st["flops"] <= 1.1 * want + 1e4


def test_collective_bytes_synthetic_hlo():
    from repro.launch.hlo_stats import collective_bytes
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %ag = f32[512]{0} all-gather(%p), replica_groups=[4,4], dimensions={0}
  %ar = f32[128]{0} all-reduce(%p), replica_groups=[4,4], to_apply=%add
  ROOT %out = f32[128]{0} add(%p, %ar)
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 512 * 4 * 3 / 4
    assert out["all-reduce"] == 2 * 128 * 4 * 3 / 4


# ---------------------------------------------------------------------------
# serving specs
# ---------------------------------------------------------------------------

def test_cache_specs_match_cache_defs():
    """Every cache leaf gets a PartitionSpec of matching rank."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.serving.engine import cache_specs

    mesh = make_mesh((1, 1), ("data", "model"))
    for name in ("qwen3-0.6b", "minicpm3-4b", "zamba2-2.7b", "rwkv6-7b"):
        cfg = get_config(name).reduced()
        defs = TF.cache_defs(cfg, batch=4, seq_len=32)
        specs = cache_specs(cfg, 4, 32, mesh, shard_seq=False)
        d_leaves = jax.tree.leaves(
            defs, is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))
        s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(d_leaves) == len(s_leaves)
        for (shape, _), spec in zip(d_leaves, s_leaves):
            assert len(spec) == len(shape)


def test_roofline_active_params_moe():
    from repro.launch.roofline import active_params
    cfg = get_config("dbrx-132b")
    total = PM.count_params(TF.param_defs(cfg))
    act = active_params(cfg)
    # dbrx: 16 experts top-4 -> active well under half of total
    assert act < 0.5 * total
    assert act > 0.05 * total
    dense = get_config("qwen3-0.6b")
    assert active_params(dense) == PM.count_params(TF.param_defs(dense))
