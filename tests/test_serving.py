"""Serving subsystem tests: fused prefill parity against sequential
decode for every cache family (incl. the windowed ring buffer),
continuous-batching scheduler continuity against isolated
single-request decodes, zero-recompile guarantees, the prefill
bucketing policy, and the telemetry channel round-trip.
"""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import params as PM
from repro.models import transformer as TF
from repro.serving import BlockTable, ServeLoop
from repro.serving.telemetry import (TRAIN_KEYS, ServeMetrics, append_row,
                                     latest_row, read_rows)

# one arch per cache family: gqa KV, rwkv recurrent state, hybrid
# (mamba conv/ssm + shared-attention KV), mla latent cache
PARITY_ARCHS = ["qwen3-0.6b", "rwkv6-7b", "zamba2-2.7b", "minicpm3-4b"]


def _init(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def _sequential_reference(cfg, params, tokens, T):
    """Teacher-forced decode_step over the prompt: the cache state the
    fused prefill must reproduce."""
    B, S = tokens.shape
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    cache = TF.init_cache(cfg, B, T, dtype)
    logits = []
    for t in range(S):
        lg, cache = TF.decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                   jnp.int32(t))
        logits.append(lg[:, 0])
    return jnp.stack(logits, axis=1), cache


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_parity(arch, rng):
    """prefill_cache (one dispatch) == S sequential decode steps: same
    logits, same cache tree, for every cache family."""
    cfg, params = _init(arch)
    B, S, T = 2, 8, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    logits_f, cache_f = TF.prefill_cache(
        cfg, params, tokens, TF.init_cache(cfg, B, T, dtype))
    logits_s, cache_s = _sequential_reference(cfg, params, tokens, T)
    np.testing.assert_allclose(np.asarray(logits_f, np.float32),
                               np.asarray(logits_s, np.float32),
                               atol=3e-2, rtol=3e-2)
    for (path_f, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(cache_f)[0],
            jax.tree_util.tree_flatten_with_path(cache_s)[0]):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-2, rtol=3e-2,
            err_msg=f"cache leaf {jax.tree_util.keystr(path_f)}")


def test_prefill_parity_windowed_ring(rng):
    """Sliding-window prefill with S > T must leave the ring buffer
    exactly as sequential decode (same slots, same overwrites)."""
    cfg, _ = _init("qwen3-0.6b")
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, window=4))
    params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    B, S, T = 2, 8, 4                       # prompt twice the ring size
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits_f, cache_f = TF.prefill_cache(
        cfg, params, tokens, TF.init_cache(cfg, B, T, jnp.bfloat16))
    logits_s, cache_s = _sequential_reference(cfg, params, tokens, T)
    np.testing.assert_allclose(np.asarray(logits_f, np.float32),
                               np.asarray(logits_s, np.float32),
                               atol=3e-2, rtol=3e-2)
    for a, b in zip(jax.tree.leaves(cache_f), jax.tree.leaves(cache_s)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-2, rtol=3e-2)


def test_scheduler_continuity(rng):
    """Requests served through the shared [max_batch] slot array emit
    exactly the tokens an isolated batch=1 greedy decode emits — dead
    slots and slot reuse never leak into live requests."""
    cfg, params = _init("qwen3-0.6b")
    max_len = 24
    prompts = [rng.integers(0, cfg.vocab, size=p) for p in (3, 5, 7, 4, 6, 5)]
    gens = [6, 4, 8, 3, 5, 7]

    loop = ServeLoop(cfg, max_batch=4, max_len=max_len, params=params)
    rids = [loop.submit(p, g) for p, g in zip(prompts, gens)]
    done = loop.run()
    assert set(done) == set(rids)

    for rid, prompt, g in zip(rids, prompts, gens):
        solo = ServeLoop(cfg, max_batch=1, max_len=max_len, params=params)
        srid = solo.submit(prompt, g)
        ref = solo.run()[srid]
        np.testing.assert_array_equal(
            done[rid], ref, err_msg=f"request {rid} diverged from its "
            f"isolated single-slot decode")
        assert len(done[rid]) == g


def test_zero_decode_recompiles(rng):
    """ONE decode compile across arbitrary join/finish churn — the
    acceptance criterion the continuous stream rides on."""
    cfg, params = _init("qwen3-0.6b")
    loop = ServeLoop(cfg, max_batch=4, max_len=32, params=params)
    for p, g in [(4, 5), (6, 3), (3, 8), (5, 2), (7, 6)]:
        loop.submit(rng.integers(0, cfg.vocab, size=p), g)
    loop.run()
    assert loop.decode_compiles() == 1
    # a second wave re-uses the compiled step
    for p, g in [(4, 3), (8, 4)]:
        loop.submit(rng.integers(0, cfg.vocab, size=p), g)
    loop.run()
    assert loop.decode_compiles() == 1
    assert loop.metrics.completed == 7


def test_prefill_bucketing_policy(rng):
    """Full-attention configs bucket prompts to power-of-two lengths
    (one compile per bucket); recurrent configs must prefill at exact
    length (padding would corrupt carried state)."""
    cfg, params = _init("qwen3-0.6b")
    loop = ServeLoop(cfg, max_batch=2, max_len=32, params=params)
    for p in (5, 6, 7, 8):                  # all land in the 8-bucket
        loop.submit(rng.integers(0, cfg.vocab, size=p), 2)
    loop.run()
    assert loop.prefill_compiles() == 1

    cfg_r, params_r = _init("rwkv6-7b")
    loop_r = ServeLoop(cfg_r, max_batch=2, max_len=32, params=params_r)
    for p in (5, 6):                        # exact-length: one compile each
        loop_r.submit(rng.integers(0, cfg_r.vocab, size=p), 2)
    loop_r.run()
    assert loop_r.prefill_compiles() == 2
    assert loop_r.decode_compiles() == 1


def test_stalled_slot_times_out_and_requeues(rng):
    """A wedged decode slot (fault ``slot_stall``) stops the request's
    progress; the watchdog requeues it and it completes from scratch —
    every request finishes, the requeue is counted, and the decode step
    never recompiles (the live mask is traced)."""
    cfg, params = _init("qwen3-0.6b")
    loop = ServeLoop(cfg, max_batch=2, max_len=24, params=params,
                     request_timeout=4)
    prompts = [rng.integers(0, cfg.vocab, size=p) for p in (3, 5, 4)]
    rids = [loop.submit(p, 6) for p in prompts]
    fired = {}

    def on_step(lp, s):
        if s == 2 and not fired:
            from repro.faults import get_spec
            ctx = type("Ctx", (), {"loop": lp, "stall_ticks": 12})()
            fired["detail"] = get_spec("slot_stall").inject(
                ctx, np.random.default_rng(0))

    done = loop.run(on_step=on_step)
    assert set(done) == set(rids)
    assert all(len(done[r]) == 6 for r in rids)
    assert loop.metrics.requeues >= 1
    assert loop.metrics.completed == 3
    assert loop.decode_compiles() == 1
    assert "stalled slot" in fired["detail"]
    # token-stream parity of requeued requests rides on the from-scratch
    # restart (tokens discarded): the greedy decode is deterministic, so
    # the retry emits the same stream test_scheduler_continuity checks


def test_serve_loop_wedge_is_loud(rng):
    """A stall with no watchdog must end in a RuntimeError, not an
    infinite idle spin."""
    cfg, params = _init("qwen3-0.6b")
    loop = ServeLoop(cfg, max_batch=1, max_len=16, params=params)
    loop.submit(rng.integers(0, cfg.vocab, size=3), 4)

    def on_step(lp, s):
        if s == 1:
            lp.inject_stall(0, 10**9)       # wedged forever, no timeout

    with pytest.raises(RuntimeError, match="wedged"):
        loop.run(on_step=on_step)


def test_block_table():
    t = BlockTable(2)
    s0, s1 = t.alloc(10), t.alloc(11)
    assert {s0, s1} == {0, 1} and not t.free_slots and len(t) == 2
    t.free(10)
    assert t.alloc(12) == s0                # slot reuse
    with pytest.raises(Exception):
        t.alloc(13)                         # full


def test_telemetry_rows_are_fsynced(tmp_path, monkeypatch):
    """Every append must flush AND fsync its row: a host crash loses at
    most the in-flight row, never buffered complete rows (the recovery
    supervisor's post-mortem reads depend on it)."""
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (synced.append(fd),
                                                 real_fsync(fd))[1])
    append_row(str(tmp_path), {"step": 0, "gnorm": 1.0, "n_selected": 6.0,
                               "n_selected_min": 5.0, "n_active": 8.0,
                               "quorum": 6})
    assert len(synced) == 1
    assert read_rows(str(tmp_path))[0]["step"] == 0


def test_telemetry_roundtrip(tmp_path):
    d = str(tmp_path)
    rows = [{"step": i, "gnorm": 1.0 + i, "n_selected": 6.0,
             "n_selected_min": 5.0, "n_active": 8.0, "quorum": 6}
            for i in range(3)]
    for r in rows:
        append_row(d, r)
    # torn trailing line (crash mid-append) must be skipped, not fatal
    with open(os.path.join(d, "telemetry.jsonl"), "a") as f:
        f.write('{"step": 3, "gnorm"')
    got = read_rows(d)
    assert [r["step"] for r in got] == [0, 1, 2]
    assert latest_row(d)["step"] == 2
    with pytest.raises(ValueError):
        append_row(d, {"step": 9})          # missing TRAIN_KEYS

    m = ServeMetrics()
    for dt in (0.002, 0.004, 0.001):
        m.observe_decode(dt, n_live=2)
    m.observe_swap(0.05)
    snap = m.snapshot(train_row=rows[-1])
    assert snap["tokens_total"] == 6 and snap["swaps"] == 1
    assert snap["latency_p50_ms"] <= snap["latency_p99_ms"]
    text = m.render(rows[-1])
    assert "repro_serve_latency_p50_ms" in text
    assert "repro_train_gnorm" in text
    for k in TRAIN_KEYS:
        assert f"repro_train_{k}" in text
