"""Distributed aggregation correctness on an 8-device host mesh.

Each test runs in a subprocess (the main pytest process keeps the real
single device); the snippets assert internally and print OK."""
import textwrap

import pytest

import meshes
from conftest import run_multidevice

COMMON = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from repro.compat import P, shard_map
    from repro.configs.base import ByzantineConfig
    from repro.core import aggregators, threat
    from repro.core.distributed import robust_aggregate
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    m = 8
""")


def test_shardmap_brsgd_equals_oracle():
    """Distributed gather-layout BrSGD == single-host aggregator on the
    same G, for several leaf shapes."""
    code = COMMON + textwrap.dedent("""
        rng = np.random.default_rng(0)
        leaves = {"a": (3, 5), "b": (17,), "c": (2, 2, 4)}
        gs = {k: rng.normal(size=(m,) + s).astype("f4") for k, s in leaves.items()}
        bcfg = ByzantineConfig(aggregator="brsgd")

        @partial(shard_map, mesh=mesh,
                 in_specs=({k: P("data") for k in gs},),
                 out_specs={k: P() for k in gs})
        def agg(tree):
            local = {k: v.reshape(v.shape[1:]) for k, v in tree.items()}
            out, st = robust_aggregate(local, bcfg, ("data",), layout="gather")
            return out

        out = agg({k: jnp.asarray(v) for k, v in gs.items()})
        # oracle: flatten to G [m, d] and run the single-host rule
        G = jnp.concatenate([jnp.asarray(v).reshape(m, -1) for v in gs.values()], axis=1)
        ref = aggregators.brsgd(G, bcfg)
        flat = jnp.concatenate([out[k].reshape(-1) for k in gs], axis=0)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(ref), rtol=1e-4, atol=1e-5)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


def test_gather_and_a2a_layouts_identical():
    code = COMMON + textwrap.dedent("""
        rng = np.random.default_rng(1)
        gs = {"w": rng.normal(size=(m, 4, 10)).astype("f4"),
              "b": rng.normal(size=(m, 3)).astype("f4")}
        bcfg = ByzantineConfig(aggregator="brsgd")

        def run(layout):
            @partial(shard_map, mesh=mesh,
                     in_specs=({k: P("data") for k in gs},),
                     out_specs={k: P() for k in gs})
            def agg(tree):
                local = {k: v.reshape(v.shape[1:]) for k, v in tree.items()}
                return robust_aggregate(local, bcfg, ("data",), layout=layout)[0]
            return agg({k: jnp.asarray(v) for k, v in gs.items()})

        o1, o2 = run("gather"), run("a2a")
        for k in gs:
            np.testing.assert_allclose(np.asarray(o1[k]), np.asarray(o2[k]),
                                       rtol=1e-4, atol=1e-5)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


def test_median_aggregator_distributed():
    code = COMMON + textwrap.dedent("""
        rng = np.random.default_rng(3)
        g = rng.normal(size=(m, 33)).astype("f4")
        bcfg = ByzantineConfig(aggregator="median")
        for layout in ("gather", "a2a"):
            @partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P())
            def agg(x):
                return robust_aggregate({"g": x.reshape(x.shape[1:])},
                                        bcfg, ("data",), layout=layout)[0]["g"]
            out = agg(jnp.asarray(g))
            np.testing.assert_allclose(np.asarray(out), np.median(g, axis=0),
                                       atol=1e-5, err_msg=layout)
        print("OK")
    """)
    assert "OK" in run_multidevice(code)


def test_train_step_loss_decreases_under_attack():
    """10 distributed BrSGD steps on a reduced qwen3 with 25% gaussian
    attackers: loss decreases; with mean aggregation it blows up."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh, n_workers
        from repro.data.pipeline import LMWorkerPipeline

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()

        def run(aggregator, steps=8):
            bcfg = ByzantineConfig(aggregator=aggregator, attack="gaussian",
                                   alpha=0.25)
            tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                               lr=0.1, grad_clip=0.0)
            bundle = build_train_step(tcfg, mesh)
            psh, osh, bsh = bundle.shardings(mesh)
            key = jax.random.PRNGKey(0)
            params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
            opt = ()
            pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)
            losses = []
            with mesh:
                for s in range(steps):
                    batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                             for k, v in pipe.batch(s).items()}
                    params, opt, met = bundle.step_fn(params, opt, batch,
                                                      jnp.int32(s),
                                                      jax.random.fold_in(key, s))
                    losses.append(float(met["loss"]))
            return losses

        brsgd = run("brsgd")
        assert brsgd[-1] < brsgd[0] - 0.01, f"brsgd no progress: {brsgd}"
        assert all(np.isfinite(brsgd)), brsgd
        mean = run("mean")
        # mean under a std-200 gaussian attack takes huge steps: the loss
        # must end far above brsgd's (diverged or stuck)
        assert (not np.isfinite(mean[-1])) or mean[-1] > brsgd[-1] + 0.5, (mean, brsgd)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, timeout=560)


def test_blocked_fsdp_aggregation_runs_and_filters():
    """agg_scope=blocked (FSDP + in-backward aggregation) on 8 devices:
    runs, keeps loss finite, and reports a non-trivial selection."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-1.7b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="scale", alpha=0.25)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd", lr=0.05,
                           agg_scope="blocked", agg_layout="a2a")
        bundle = build_train_step(tcfg, mesh)
        assert bundle.scope == "blocked"
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        from repro.data.pipeline import LMWorkerPipeline
        pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)
        losses = []
        with mesh:
            for s in range(6):
                batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                         for k, v in pipe.batch(s).items()}
                params, _, met = bundle.step_fn(params, (), batch, jnp.int32(s),
                                                jax.random.fold_in(key, s))
                losses.append(float(met["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("OK")
    """)
    assert "OK" in run_multidevice(code, timeout=560)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
@pytest.mark.parametrize("layout", ["gather", "a2a"])
def test_global_train_step_mesh_matrix(mesh_name, layout):
    """End-to-end GLOBAL-scope train step on the mesh matrix: on the
    data×model mesh the loss runs auto-SPMD with tensor parallelism and
    only the aggregation region enters (full-)manual mode — the
    configuration that used to die in XLA SPMD partitioning
    (PartitionId / IsManualSubgroup).  Under a scale attack brsgd must
    reject the byzantine worker (n_selected < m) and keep the loss
    finite, in BOTH collective layouts."""
    code = meshes.preamble(mesh_name, 4) + textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.data.pipeline import LMWorkerPipeline

        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="scale",
                               alpha=0.25)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope="global",
                           agg_layout={layout!r})
        bundle = build_train_step(tcfg, mesh)
        assert bundle.scope == "global" and bundle.layout == {layout!r}
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, m, 2, 32, byz=bcfg)
        with mesh:
            for s in range(2):
                batch = {{k: jax.device_put(jnp.asarray(v), bsh[k])
                          for k, v in pipe.batch(s).items()}}
                params, _, met = bundle.step_fn(params, (), batch,
                                                jnp.int32(s),
                                                jax.random.fold_in(key, s))
        met = {{k: float(v) for k, v in met.items()}}
        assert np.isfinite(met["loss"]), met
        assert 0 < met["n_selected"] < m, met      # 1/4 byzantine rejected
        print("OK")
    """)
    assert "OK" in run_multidevice(code,
                                   n_devices=meshes.n_devices(mesh_name, 4),
                                   timeout=560)


def test_multipod_mesh_axes():
    code = textwrap.dedent("""
        from repro.launch.mesh import make_production_mesh, worker_axes, n_workers
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.shape == (16, 16)
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.shape == (2, 16, 16)
        assert worker_axes(m2) == ("pod", "data") and n_workers(m2) == 32
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=512)
