"""End-to-end behaviour: the paper's experiment (m=20 workers, LeNet,
four attacks) at reduced scale — BrSGD tracks the attack-free baseline
while the naive mean collapses.  This is the Table-1/Fig-3 claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ByzantineConfig
from repro.configs.lenet_fmnist import LeNetConfig
from repro.core.simulate import make_sim_step, tree_to_vec, vec_to_tree, \
    worker_grad_matrix
from repro.data.pipeline import ImageWorkerPipeline
from repro.models import lenet
from repro.models.params import init_params

M = 20          # paper worker count
STEPS = 30
LR = 0.05


def _train(aggregator: str, attack: str, alpha: float, steps: int = STEPS,
           seed: int = 0):
    cfg = LeNetConfig()
    bcfg = ByzantineConfig(aggregator=aggregator, attack=attack, alpha=alpha)
    pipe = ImageWorkerPipeline(M, n_per_worker=64, seed=seed, byz=bcfg)
    params = init_params(lenet.lenet_defs(cfg), jax.random.PRNGKey(seed))
    step = make_sim_step(lambda p, b: lenet.lenet_loss(p, b), bcfg, LR)
    key = jax.random.PRNGKey(seed + 1)
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s, 8).items()}
        params, met = step(params, batch, jax.random.fold_in(key, s))
    acc = float(lenet.lenet_accuracy(params, jnp.asarray(pipe.test_images),
                                     jnp.asarray(pipe.test_labels)))
    return acc, params, {k: float(v) for k, v in met.items()}


@pytest.fixture(scope="module")
def baseline_acc():
    acc, _, met = _train("mean", "none", 0.0)
    assert acc > 0.5, f"attack-free baseline failed to learn ({acc})"
    assert met["n_selected"] == M    # mean has no selection phase
    return acc


@pytest.mark.parametrize("attack", ["gaussian", "negation", "scale",
                                    "label_flip"])
def test_brsgd_matches_attack_free_baseline(baseline_acc, attack):
    """Paper Table 1: BrSGD under 25% attackers ~ attack-free accuracy.

    label_flip corrupts DATA (gradients look legitimate), so convergence
    is slowed rather than prevented — it gets a longer run and a wider
    mid-training band, matching the paper's Fig-3 curves."""
    steps = STEPS + 20 if attack == "label_flip" else STEPS
    acc, params, met = _train("brsgd", attack, alpha=0.25, steps=steps)
    assert np.isfinite(np.asarray(tree_to_vec(params))).all()
    margin = 0.25 if attack == "label_flip" else 0.15
    assert acc > baseline_acc - margin, f"{attack}: {acc} vs base {baseline_acc}"
    # the sim step reports the REAL selection (the seed returned only a
    # norm): gradient attackers must have been rejected
    assert 0 < met["n_selected"] <= M
    if attack != "label_flip":
        assert met["n_selected"] < M, met


@pytest.mark.parametrize("attack", ["gaussian", "negation"])
def test_mean_collapses_under_attack(baseline_acc, attack):
    """Paper Fig 3 (a0/a1): naive mean is destroyed by gradient attacks
    at alpha=0.25."""
    acc, params, _ = _train("mean", attack, alpha=0.25)
    vec = np.asarray(tree_to_vec(params))
    assert (not np.isfinite(vec).all()) or acc < baseline_acc - 0.2


def test_brsgd_alpha_half_still_learns(baseline_acc):
    """alpha just under 1/2 with beta=1/2 (paper setting)."""
    acc, _, _ = _train("brsgd", "scale", alpha=0.45)
    assert acc > baseline_acc - 0.2


def test_median_resilient_but_runs():
    """Median survives the attack but converges slower than BrSGD —
    exactly the paper's Fig-3 (b1/b3) observation."""
    acc, _, _ = _train("median", "gaussian", alpha=0.25, steps=40)
    assert acc > 0.3


def test_worker_grad_matrix_shape():
    cfg = LeNetConfig()
    params = init_params(lenet.lenet_defs(cfg), jax.random.PRNGKey(0))
    pipe = ImageWorkerPipeline(4, 16)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0, 4).items()}
    G = worker_grad_matrix(lambda p, b: lenet.lenet_loss(p, b), params, batch)
    d = tree_to_vec(params).size
    assert G.shape == (4, d)
    assert bool(jnp.isfinite(G).all())


def test_vec_tree_roundtrip():
    cfg = LeNetConfig()
    params = init_params(lenet.lenet_defs(cfg), jax.random.PRNGKey(0))
    vec = tree_to_vec(params)
    back = vec_to_tree(vec, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
