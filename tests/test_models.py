"""Per-architecture smoke tests: reduced variant of each assigned family
runs one forward/train step on CPU with correct shapes and no NaNs, and
the decode path agrees with the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import params as PM
from repro.models import transformer as TF

ARCH_IDS = list(ARCHS)


@pytest.fixture(scope="module")
def built():
    """Init reduced params once per arch (module scope for speed)."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = ARCHS[name].reduced()
            params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.n_prefix_tokens:
        out["prefix_embed"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("name", ARCH_IDS)
def test_reduced_config_limits(name):
    cfg = ARCHS[name].reduced()
    assert cfg.d_model <= 512 and cfg.n_layers <= 4
    assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(built, name):
    cfg, params = built(name)
    batch = _batch(cfg)
    logits, aux = TF.forward(cfg, params, batch["tokens"],
                             batch.get("prefix_embed"))
    S = batch["tokens"].shape[1] + cfg.n_prefix_tokens
    assert logits.shape == (2, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_one_train_step_reduces_loss_direction(built, name):
    """loss_fn gradient step with a small lr must not produce NaN and the
    loss on the SAME batch must not increase (descent direction)."""
    cfg, params = built(name)
    batch = _batch(cfg, B=2, S=16)
    loss0, _ = TF.loss_fn(cfg, params, batch)
    grads = jax.grad(lambda p: TF.loss_fn(cfg, p, batch)[0])(params)
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss1, _ = TF.loss_fn(cfg, params2, batch)
    assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 1e-4, name


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_matches_forward(built, name):
    """Token-by-token decode logits == full-sequence forward logits.

    MoE archs are compared at lossless capacity: GShard capacity drops
    legitimately differ between a T=B*S prefill dispatch and a T=B
    decode dispatch (test_moe_ssm covers the dropping path)."""
    cfg, params = built(name)
    if cfg.n_prefix_tokens:
        pytest.skip("prefix-embed archs prefill differently (tested via fwd)")
    if cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
        params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = TF.forward(cfg, params, toks)

    cache = TF.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = TF.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
        outs.append(logits.reshape(B, -1))
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_mask_limits_context():
    from repro.models.layers import _causal_window_mask
    m = np.asarray(_causal_window_mask(8, 8, window=3))
    assert m[5, 5] and m[5, 3] and not m[5, 2] and not m[3, 5]


def test_window_variant_selected_for_long500k():
    from repro.configs import get_shape
    from repro.launch.specs import variant_for_shape
    cfg = get_config("qwen3-0.6b")
    v = variant_for_shape(cfg, get_shape("long_500k"))
    assert v.attention.window == 8192
    # MLA/ssm archs keep their native path
    v2 = variant_for_shape(get_config("minicpm3-4b"), get_shape("long_500k"))
    assert v2.attention.window == 0
    v3 = variant_for_shape(get_config("rwkv6-7b"), get_shape("long_500k"))
    assert v3.attention.kind == "none"


def test_windowed_decode_ring_buffer_matches_forward():
    """Sliding-window decode with a rolling cache == windowed forward."""
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, window=4))
    params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full, _ = TF.forward(cfg, params, toks)
    cache = TF.init_cache(cfg, B, S, jnp.float32)   # T = window = 4
    for t in range(S):
        logits, cache = TF.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits.reshape(-1)),
                                   np.asarray(full[0, t]), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ["deepseek-v2-236b", "dbrx-132b"])
def test_full_config_param_counts(name):
    """Full (non-reduced) configs match the published scale."""
    cfg = get_config(name)
    n = PM.count_params(TF.param_defs(cfg))
    expected = {"deepseek-v2-236b": 236e9, "dbrx-132b": 132e9}[name]
    assert 0.75 * expected < n < 1.35 * expected, f"{name}: {n:.3e}"


def test_param_specs_cover_every_leaf():
    """pspec_tree yields a PartitionSpec for every ParamDef leaf."""
    import jax.sharding as shd
    from repro.launch.mesh import make_mesh
    # a fake mesh over 1 device still produces specs
    mesh = make_mesh((1, 1), ("data", "model"))
    for name in ARCH_IDS:
        defs = TF.param_defs(get_config(name))
        specs = PM.pspec_tree(defs, mesh)
        n_defs = len(jax.tree.leaves(defs, is_leaf=PM.is_param_def))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, shd.PartitionSpec)))
        assert n_defs == n_specs
