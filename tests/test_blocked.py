"""Blocked (FSDP, in-backward) aggregation on the engine registry.

The parity matrix runs every registered aggregator through
``core.blocked._bucket_aggregate`` on a 4-device CPU mesh and compares
against the local [m, d] execution of the SAME registry entry — a
single bucket's bucket-local selection IS the global selection, so the
two must agree.  The bucket mixes all three leaf classes: an
FSDP-sharded leaf (in-place a2a), a replicated leaf with numel % m != 0
(flat zero-pad a2a + pad_correction), and a nominally-sharded but
non-divisible leaf (flat-path fallback).

Also covered: truthful ``n_selected`` under attack (the seed always
reported m in blocked scope), and decorrelated per-bucket attack noise
(the seed reused one key for every bucket hook).
"""
import textwrap

import pytest

from conftest import run_multidevice

COMMON = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from repro.compat import P, shard_map
    from repro.configs.base import ByzantineConfig
    from repro.core import engine
    from repro.core.blocked import (_bucket_aggregate, bucket_key,
                                    key_carrier, make_fsdp_agg_barrier,
                                    selection_token)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    axes = ("data",)
    m = 4
    rng = np.random.default_rng(0)
    # "w": FSDP dim 0 (8 % 4 == 0)         -> in-place a2a worker view
    # "b": replicated, numel 7 (7 % 4 != 0) -> flat zero-pad a2a path
    # "u": sharded spec but 6 % 4 != 0      -> flat-path fallback
    specs = {"w": P("data", None), "b": P(None), "u": P("data")}
    full = {"w": rng.normal(size=(m, 8, 6)).astype("f4"),
            "b": rng.normal(size=(m, 7)).astype("f4"),
            "u": rng.normal(size=(m, 6)).astype("f4")}
    SHARDED = {"w": 0}          # leaves whose output is the local shard

    def flatG(tree):
        return np.concatenate([np.asarray(v).reshape(m, -1)
                               for v in tree.values()], axis=1)

    def blocked(cfg, tree):
        @partial(shard_map, mesh=mesh,
                 in_specs=({k: P("data") for k in tree},),
                 out_specs=({k: P() for k in tree}, P()))
        def run(t):
            local = {k: v.reshape(v.shape[1:]) for k, v in t.items()}
            out, st = _bucket_aggregate(local, specs, cfg, axes)
            out = {k: (jax.lax.all_gather(v, axes, axis=SHARDED[k],
                                          tiled=True)
                       if k in SHARDED else v) for k, v in out.items()}
            return out, jnp.sum(st.selected.astype(jnp.float32))
        out, n_sel = run({k: jnp.asarray(v) for k, v in tree.items()})
        flat = np.concatenate([np.asarray(out[k]).reshape(-1)
                               for k in tree])
        return flat, float(n_sel)
""")


def test_blocked_vs_global_parity_all_aggregators():
    """Every registered rule — not just brsgd/mean — runs in blocked
    scope and matches the local execution of the same registry entry."""
    code = COMMON + textwrap.dedent("""
        for name in engine.registered():
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            want = np.asarray(engine.aggregate_local(
                jnp.asarray(flatG(full)), cfg))
            got, _ = blocked(cfg, full)
            # geomedian's distributed Weiszfeld runs in Gram space —
            # same fixed point, different rounding path
            tol = 1e-3 if name == "geomedian" else 1e-5
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=tol,
                                       err_msg=name)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_blocked_selection_truthful_under_attack():
    """One worker scaled by 1e6: the bucket's SelectionState must report
    n_selected < m, exactly matching the global rule's selection, and
    the aggregate must stay near the honest one."""
    code = COMMON + textwrap.dedent("""
        evil = {k: v.copy() for k, v in full.items()}
        for k in evil:
            evil[k][0] *= 1e6                 # worker 0 byzantine
        cfg = ByzantineConfig(aggregator="brsgd", alpha=0.25)
        _, st = engine.aggregate_local(jnp.asarray(flatG(evil)), cfg,
                                       return_state=True)
        want_sel = float(jnp.sum(st.selected.astype(jnp.float32)))
        got, n_sel = blocked(cfg, evil)
        assert n_sel == want_sel, (n_sel, want_sel)
        assert 0 < n_sel < m, n_sel
        assert not bool(st.selected[0]), "byzantine row not rejected"
        # the ×1e6 row must not leak: the attacked aggregate stays
        # within O(1) honest-row spread of the attack-free aggregate
        # (the two runs may select different honest subsets)
        honest, _ = blocked(cfg, full)
        assert np.abs(got - honest).max() < 5.0, "attack leaked into aggregate"
        # krum always combines exactly one row
        _, k_sel = blocked(ByzantineConfig(aggregator="krum", alpha=0.25),
                           evil)
        assert k_sel == 1.0, k_sel
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_bucket_attack_noise_decorrelated():
    """Regression: two buckets fed the SAME step key must inject
    DIFFERENT gaussian noise (the seed passed one key to every hook, so
    all buckets received bit-identical noise — a correlated attack
    weaker than the threat model).  Likewise two LAYERS of one scanned
    segment (same hook, different scan index) must differ.  Every
    barrier now receives the RAW step key; the bucket name folds into
    the noise key inside the barrier's backward."""
    code = COMMON + textwrap.dedent("""
        bspecs = {"w": P("data", None)}
        bcfg = ByzantineConfig(aggregator="mean", attack="gaussian",
                               alpha=0.5)
        key = jax.random.PRNGKey(7)
        kf = key_carrier(key)
        ct = {"w": jnp.asarray(rng.normal(size=(8, 6)).astype("f4"))}

        def run_bucket(name, layer=0.0):
            hook = make_fsdp_agg_barrier(bspecs, bcfg, axes, name)
            @partial(shard_map, mesh=mesh, in_specs=(P(),),
                     out_specs=P("data"))
            def f(ct_full):
                p = {"w": jnp.zeros((2, 6), jnp.float32)}   # local shard
                _, vjp = jax.vjp(hook, p, selection_token(m),
                                 jnp.float32(layer), kf)
                agg, hist, _, _ = vjp(ct_full)
                return agg["w"]
            return np.asarray(f(ct))

        a, b = run_bucket("seg_0"), run_bucket("seg_1")
        np.testing.assert_array_equal(a, run_bucket("seg_0"))  # determinism
        assert not np.allclose(a, b), "bucket noise is bit-identical"
        # intra-segment: same hook, different scan position
        a1 = run_bucket("seg_0", layer=1.0)
        assert not np.allclose(a, a1), "layer noise is bit-identical"
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=4)


def test_blocked_step_reports_true_selection():
    """End-to-end blocked train step under a scale attack: n_selected
    comes from the real per-bucket selections (< m; the seed hard-coded
    m), with n_selected_min <= n_selected."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh
        from repro.data.pipeline import LMWorkerPipeline

        mesh = make_mesh((8,), ("data",))
        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="scale", alpha=0.25)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope="blocked", agg_layout="a2a")
        bundle = build_train_step(tcfg, mesh)
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, 8, 2, 32, byz=bcfg)
        with mesh:
            for s in range(2):
                batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                         for k, v in pipe.batch(s).items()}
                params, _, met = bundle.step_fn(params, (), batch,
                                                jnp.int32(s),
                                                jax.random.fold_in(key, s))
        met = {k: float(v) for k, v in met.items()}
        assert np.isfinite(met["loss"]), met
        assert met["n_selected"] < 8, met          # 2/8 byzantine rejected
        assert 0 < met["n_selected_min"] <= met["n_selected"], met
        print("OK")
    """)
    assert "OK" in run_multidevice(code, timeout=560)
