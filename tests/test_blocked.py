"""Blocked (FSDP, in-backward) aggregation on the engine registry.

The parity matrix runs every registered aggregator through
``core.blocked._bucket_aggregate`` and compares against the local
[m, d] execution of the SAME registry entry — a single bucket's
bucket-local selection IS the global selection, so the two must agree.
It runs over the mesh matrix in ``tests/meshes.py``: the worker-only
CPU mesh AND a data×model mesh — blocked scope folds the 'model' axis
into the FSDP worker set (every mesh axis is a worker axis, the step is
one full-manual shard_map; DESIGN.md §Mesh), so the (4,2) case runs
m = 8 workers.  The bucket mixes all three leaf classes: an
FSDP-sharded leaf (in-place a2a), a replicated leaf with numel % m != 0
(flat zero-pad a2a + pad_correction), and a nominally-sharded but
non-divisible leaf (flat-path fallback).

Also covered: truthful ``n_selected`` under attack (the seed always
reported m in blocked scope), decorrelated per-bucket attack noise
(the seed reused one key for every bucket hook), and a jaxpr-level pin
that the barrier backward never falls back to gathering an m×-sized
worker matrix (the no-all_gather-fallback guarantee that used to be
ROADMAP prose).
"""
import textwrap

import pytest

import meshes
from conftest import run_multidevice


def _common(mesh_name: str) -> str:
    """Bucket fixture on one mesh-matrix entry.  Blocked scope's worker
    set is EVERY mesh axis (BAXES/bm from tests/meshes.py)."""
    return meshes.preamble(mesh_name, 4) + textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.compat import shard_map
        from repro.configs.base import ByzantineConfig
        from repro.core import engine
        from repro.core.blocked import (_bucket_aggregate, bucket_key,
                                        key_carrier, make_fsdp_agg_barrier,
                                        selection_token)

        axes = BAXES
        m = bm
        rng = np.random.default_rng(0)
        # "w": FSDP dim 0 (2m % m == 0)          -> in-place a2a worker view
        # "b": replicated, numel 7 (7 % m != 0)  -> flat zero-pad a2a path
        # "u": sharded spec but numel m-2        -> flat-path fallback
        specs = {"w": P(bspec, None), "b": P(None), "u": P(bspec)}
        full = {"w": rng.normal(size=(m, 2 * m, 6)).astype("f4"),
                "b": rng.normal(size=(m, 7)).astype("f4"),
                "u": rng.normal(size=(m, m - 2)).astype("f4")}
        SHARDED = {"w": 0}          # leaves whose output is the local shard

        def flatG(tree):
            return np.concatenate([np.asarray(v).reshape(m, -1)
                                   for v in tree.values()], axis=1)

        def blocked(cfg, tree):
            @partial(shard_map, mesh=mesh,
                     in_specs=({k: P(bspec) for k in tree},),
                     out_specs=({k: P() for k in tree}, P()))
            def run(t):
                local = {k: v.reshape(v.shape[1:]) for k, v in t.items()}
                out, st = _bucket_aggregate(local, specs, cfg, axes)
                out = {k: (jax.lax.all_gather(v, axes, axis=SHARDED[k],
                                              tiled=True)
                           if k in SHARDED else v) for k, v in out.items()}
                return out, jnp.sum(st.selected.astype(jnp.float32))
            out, n_sel = run({k: jnp.asarray(v) for k, v in tree.items()})
            flat = np.concatenate([np.asarray(out[k]).reshape(-1)
                                   for k in tree])
            return flat, float(n_sel)
    """)


def _devices(mesh_name: str) -> int:
    return meshes.n_devices(mesh_name, 4)


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_blocked_vs_global_parity_all_aggregators(mesh_name):
    """Every registered rule — not just brsgd/mean — runs in blocked
    scope and matches the local execution of the same registry entry,
    on the worker-only AND the data×model mesh."""
    code = _common(mesh_name) + textwrap.dedent("""
        for name in engine.registered():
            cfg = ByzantineConfig(aggregator=name, alpha=0.25)
            want = np.asarray(engine.aggregate_local(
                jnp.asarray(flatG(full)), cfg))
            got, _ = blocked(cfg, full)
            # geomedian's distributed Weiszfeld runs in Gram space —
            # same fixed point, different rounding path
            tol = 1e-3 if name == "geomedian" else 1e-5
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=tol,
                                       err_msg=name)
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=_devices(mesh_name))


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_blocked_selection_truthful_under_attack(mesh_name):
    """One worker scaled by 1e6: the bucket's SelectionState must report
    n_selected < m, exactly matching the global rule's selection, and
    the aggregate must stay near the honest one."""
    code = _common(mesh_name) + textwrap.dedent("""
        evil = {k: v.copy() for k, v in full.items()}
        for k in evil:
            evil[k][0] *= 1e6                 # worker 0 byzantine
        cfg = ByzantineConfig(aggregator="brsgd", alpha=0.25)
        _, st = engine.aggregate_local(jnp.asarray(flatG(evil)), cfg,
                                       return_state=True)
        want_sel = float(jnp.sum(st.selected.astype(jnp.float32)))
        got, n_sel = blocked(cfg, evil)
        assert n_sel == want_sel, (n_sel, want_sel)
        assert 0 < n_sel < m, n_sel
        assert not bool(st.selected[0]), "byzantine row not rejected"
        # the ×1e6 row must not leak: the attacked aggregate stays
        # within O(1) honest-row spread of the attack-free aggregate
        # (the two runs may select different honest subsets)
        honest, _ = blocked(cfg, full)
        assert np.abs(got - honest).max() < 5.0, "attack leaked into aggregate"
        # krum always combines exactly one row
        _, k_sel = blocked(ByzantineConfig(aggregator="krum", alpha=0.25),
                           evil)
        assert k_sel == 1.0, k_sel
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=_devices(mesh_name))


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_bucket_attack_noise_decorrelated(mesh_name):
    """Regression: two buckets fed the SAME step key must inject
    DIFFERENT gaussian noise (the seed passed one key to every hook, so
    all buckets received bit-identical noise — a correlated attack
    weaker than the threat model).  Likewise two LAYERS of one scanned
    segment (same hook, different scan index) must differ.  Every
    barrier now receives the RAW step key; the bucket name folds into
    the noise key inside the barrier's backward."""
    code = _common(mesh_name) + textwrap.dedent("""
        bspecs = {"w": P(bspec, None)}
        bcfg = ByzantineConfig(aggregator="mean", attack="gaussian",
                               alpha=0.5)
        key = jax.random.PRNGKey(7)
        kf = key_carrier(key)
        ct = {"w": jnp.asarray(rng.normal(size=(2 * m, 6)).astype("f4"))}

        def run_bucket(name, layer=0.0):
            hook = make_fsdp_agg_barrier(bspecs, bcfg, axes, name)
            @partial(shard_map, mesh=mesh, in_specs=(P(),),
                     out_specs=P(bspec))
            def f(ct_full):
                p = {"w": jnp.zeros((2, 6), jnp.float32)}   # local shard
                _, vjp = jax.vjp(hook, p, selection_token(m),
                                 jnp.float32(layer), kf)
                agg, hist, _, _ = vjp(ct_full)
                return agg["w"]
            return np.asarray(f(ct))

        a, b = run_bucket("seg_0"), run_bucket("seg_1")
        np.testing.assert_array_equal(a, run_bucket("seg_0"))  # determinism
        assert not np.allclose(a, b), "bucket noise is bit-identical"
        # intra-segment: same hook, different scan position
        a1 = run_bucket("seg_0", layer=1.0)
        assert not np.allclose(a, a1), "layer noise is bit-identical"
        print("OK")
    """)
    assert "OK" in run_multidevice(code, n_devices=_devices(mesh_name))


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_blocked_backward_never_gathers_worker_matrix(mesh_name):
    """Jaxpr-level pin of the no-fallback guarantee (previously ROADMAP
    prose): the barrier BACKWARD keeps every leaf on the 1×-memory a2a
    path — the only all_gathers it may contain are the re-assembly of
    already-aggregated flat chunks (``engine.unchunk``), whose output is
    one leaf, never m× one leaf.  A gather-layout fallback would emit an
    all_gather whose output is m·numel(leaf) — the
    ``no-worker-gather-in-blocked-bwd`` rule from ``repro.analysis``
    (the repo's single jaxpr walker) asserts no all_gather payload
    exceeds the largest padded leaf, on BOTH mesh shapes."""
    code = _common(mesh_name) + textwrap.dedent("""
        import math
        bcfg = ByzantineConfig(aggregator="brsgd", alpha=0.25)
        bspecs = {"w": P(bspec, None), "b": P(None)}
        hook = make_fsdp_agg_barrier(bspecs, bcfg, axes, "seg_0")
        kf = key_carrier(jax.random.PRNGKey(0))

        def bwd_only(p, ct):
            _, vjp = jax.vjp(hook, p, selection_token(m), jnp.float32(0), kf)
            return vjp(ct)

        @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P())
        def traced(_):
            p = {"w": jnp.zeros((2, 6), jnp.float32),    # local FSDP shard
                 "b": jnp.zeros((7,), jnp.float32)}      # replicated
            ct = {"w": jnp.zeros((2 * m, 6), jnp.float32),
                  "b": jnp.zeros((7,), jnp.float32)}
            out = bwd_only(p, ct)
            return sum(jnp.sum(x) for x in jax.tree.leaves(out))

        from repro.analysis import extract
        from repro.analysis.rules import RuleContext, run_rules

        contract = extract(jax.make_jaxpr(traced)(jnp.float32(0)))
        gathers = contract.of_kind("all_gather")
        assert gathers, "expected unchunk all_gathers in the backward"
        # largest leaf (the FSDP "w") padded to a multiple of m
        leaf_max = max(2 * m * 6, m * math.ceil(7 / m), m)
        ctx = RuleContext(case="barrier-bwd", layout="blocked", m=m,
                          max_gather_numel=leaf_max)
        vs = run_rules(contract, ctx,
                       rules=["no-worker-gather-in-blocked-bwd"])
        assert not vs, [v.format() for v in vs]
        print("OK", len(gathers))
    """)
    assert "OK" in run_multidevice(code, n_devices=_devices(mesh_name))


@pytest.mark.mesh_matrix
@pytest.mark.parametrize("mesh_name", meshes.mesh_names())
def test_blocked_step_reports_true_selection(mesh_name):
    """End-to-end blocked train step under a scale attack: n_selected
    comes from the real per-bucket selections (< m; the seed hard-coded
    m), with n_selected_min <= n_selected — on the worker-only mesh AND
    the (4,2) data×model mesh (8 workers, 'model' folded into the FSDP
    worker set)."""
    shape, axes = ((8,), ("data",)) if mesh_name == "flat" else \
        ((4, 2), ("data", "model"))
    code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, TrainConfig, ByzantineConfig
        from repro.training.step import build_train_step
        from repro.models import transformer as TF, params as PM
        from repro.launch.mesh import make_mesh, n_workers
        from repro.data.pipeline import LMWorkerPipeline

        mesh = make_mesh({shape!r}, {axes!r})
    """) + textwrap.dedent("""
        cfg = ARCHS["qwen3-0.6b"].reduced()
        bcfg = ByzantineConfig(aggregator="brsgd", attack="scale", alpha=0.25)
        tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                           lr=0.05, agg_scope="blocked", agg_layout="a2a")
        bundle = build_train_step(tcfg, mesh)
        m = n_workers(mesh, bundle.scope)
        assert m == 8, m
        psh, osh, bsh = bundle.shardings(mesh)
        key = jax.random.PRNGKey(0)
        params = jax.device_put(PM.init_params(TF.param_defs(cfg), key), psh)
        pipe = LMWorkerPipeline(cfg, m, 2, 32, byz=bcfg)
        with mesh:
            for s in range(2):
                batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                         for k, v in pipe.batch(s).items()}
                params, _, met = bundle.step_fn(params, (), batch,
                                                jnp.int32(s),
                                                jax.random.fold_in(key, s))
        met = {k: float(v) for k, v in met.items()}
        assert np.isfinite(met["loss"]), met
        assert met["n_selected"] < 8, met          # 2/8 byzantine rejected
        assert 0 < met["n_selected_min"] <= met["n_selected"], met
        print("OK")
    """)
    assert "OK" in run_multidevice(code, timeout=560)
