"""Paper repro (Section 5): LeNet on FashionMNIST-like data, m=20
workers, four attacks — the Fig-3 experiment at example scale.

  PYTHONPATH=src python examples/byzantine_lenet.py [--steps 60]
"""
import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))
sys.path.insert(0, _REPO)

from benchmarks.common import train_lenet  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--alpha", type=float, default=0.25)
    args = ap.parse_args()

    base, _ = train_lenet("mean", "none", 0.0, steps=args.steps)
    print(f"attack-free baseline accuracy: {base:.3f}\n")
    print(f"{'attack':<12} {'brsgd':>8} {'median':>8} {'mean':>8}")
    for attack in ("gaussian", "negation", "scale", "label_flip"):
        row = []
        for agg in ("brsgd", "median", "mean"):
            acc, _ = train_lenet(agg, attack, args.alpha, steps=args.steps)
            row.append(acc)
        print(f"{attack:<12} {row[0]:>8.3f} {row[1]:>8.3f} {row[2]:>8.3f}")
    print(f"\n(baseline {base:.3f}; paper claim: brsgd column ~ baseline, "
          f"mean column collapses under gaussian/negation)")


if __name__ == "__main__":
    main()
