"""End-to-end driver: distributed BrSGD training of a qwen3-family LM
with simulated Byzantine workers.

Default (CPU-tractable): reduced model, 8 host devices, 30 steps.
``--full`` selects a ~100M-parameter model for a few hundred steps —
the deliverable-(b) configuration (expect hours on CPU; minutes on
accelerators).

  PYTHONPATH=src JAX_NUM_CPU_DEVICES=8 python examples/train_100m.py
  PYTHONPATH=src JAX_NUM_CPU_DEVICES=8 python examples/train_100m.py --full
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps, seq 512")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--attack", default="gaussian")
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default="results/train_100m")
    args = ap.parse_args()

    from repro.launch import train as T

    if args.full:
        # ~100M-param qwen3-family config: registered on the fly so the
        # stock driver can select it.
        from repro import configs
        base = configs.get_config("qwen3-0.6b")
        cfg100 = dataclasses.replace(
            base, name="qwen3-100m", n_layers=12, d_model=768, d_ff=2048,
            vocab=32768,
            attention=dataclasses.replace(base.attention, n_heads=12,
                                          n_kv_heads=4, head_dim=64))
        configs.ARCHS["qwen3-100m"] = cfg100
        argv = ["--arch", "qwen3-100m", "--steps", str(args.steps or 300),
                "--batch-per-worker", "4", "--seq", "512"]
    else:
        argv = ["--arch", "qwen3-0.6b", "--reduced",
                "--steps", str(args.steps or 30),
                "--batch-per-worker", "2", "--seq", "128"]
    argv += ["--attack", args.attack, "--alpha", str(args.alpha),
             "--aggregator", "brsgd", "--ckpt-dir", args.ckpt_dir]
    history = T.main(argv)
    losses = [h["loss"] for h in history]
    assert losses[-1] < losses[0], f"no training progress: {losses}"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} under "
          f"{args.attack}@{args.alpha:.0%} with BrSGD aggregation")


if __name__ == "__main__":
    main()
