"""Serving demo: batched prefill + greedy decode for any assigned
architecture (reduced variant on CPU).

  PYTHONPATH=src python examples/serve_demo.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_demo.py --arch rwkv6-7b --gen 32
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.launch import serve as S
    S.main(["--arch", args.arch, "--reduced", "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
