"""Serving demo: fused prefill + greedy decode for any assigned
architecture, plus the end-to-end robust train→serve loop.

  PYTHONPATH=src python examples/serve_demo.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_demo.py --arch rwkv6-7b --gen 32

End-to-end: train under attack with periodic (atomic) checkpointing,
then serve a continuous request stream while a later checkpoint is
published mid-stream — the server hot-swaps it under live decode and
keeps answering (zero dropped requests, zero decode recompiles):

  PYTHONPATH=src python examples/serve_demo.py --train-and-serve
"""
import argparse
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def publish(src_dir, dst_dir, step):
    """Copy one checkpoint between directories, manifest LAST so a
    concurrently-polling HotSwapper never sees a torn step."""
    os.makedirs(dst_dir, exist_ok=True)
    name = f"step_{step:08d}"
    for ext in (".npz", ".json"):          # manifest-last protocol
        tmp = os.path.join(dst_dir, name + ext + ".tmp")
        shutil.copy(os.path.join(src_dir, name + ext), tmp)
        os.rename(tmp, os.path.join(dst_dir, name + ext))


def train_and_serve(args):
    """Train under attack with checkpointing; serve with a hot swap
    mid-stream.  Deterministic in CI: training finishes first, the swap
    is forced by publishing a later checkpoint from the decode loop."""
    import numpy as np

    from repro.launch import train as T
    from repro.configs import get_config
    from repro.models import params as PM
    from repro.models import transformer as TF
    from repro.serving import HotSwapper, ServeLoop, latest_row

    stage = tempfile.mkdtemp(prefix="repro_stage_")
    live = tempfile.mkdtemp(prefix="repro_live_")
    steps = 5
    T.main(["--arch", args.arch, "--reduced", "--steps", str(steps),
            "--seq", "32", "--batch-per-worker", "1",
            "--attack", "sign_flip", "--alpha", "0.25",
            "--ckpt-dir", stage, "--ckpt-every", "2"])
    shutil.copy(os.path.join(stage, "telemetry.jsonl"),
                os.path.join(live, "telemetry.jsonl"))
    publish(stage, live, 2)                # serve starts on step 2

    import jax
    cfg = get_config(args.arch).reduced()
    like = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(args.seed))
    swapper = HotSwapper(live, like=like)
    assert swapper.loaded_step == 2
    loop = ServeLoop(cfg, max_batch=4, max_len=args.prompt_len + args.gen,
                     swapper=swapper)
    rng = np.random.RandomState(args.seed)
    for _ in range(8):
        plen = rng.randint(3, args.prompt_len + 1)
        loop.submit(rng.randint(0, cfg.vocab, size=plen), max_new=args.gen)

    def on_step(lp, s):
        if s == 3:                         # force a swap under live decode
            publish(stage, live, steps)

    done = loop.run(on_step=on_step)
    assert len(done) == 8, f"dropped requests: {8 - len(done)}"
    assert swapper.swap_count >= 1, "no hot swap happened"
    assert swapper.loaded_step == steps
    assert loop.decode_compiles() == 1, \
        f"decode recompiled: {loop.decode_compiles()} compiles"
    print(f"train->serve OK: 8/8 requests, {swapper.swap_count} swap(s), "
          f"1 decode compile, serving step {swapper.loaded_step}")
    print(loop.metrics.render(latest_row(live)), end="")
    shutil.rmtree(stage)
    shutil.rmtree(live)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true",
                    help="run the full (non-reduced) config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-and-serve", action="store_true",
                    help="end-to-end: train under attack with "
                         "checkpointing, serve across a live hot swap")
    args = ap.parse_args()

    if args.train_and_serve:
        return train_and_serve(args)

    from repro.launch import serve as S
    argv = ["--arch", args.arch, "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len), "--gen", str(args.gen),
            "--seed", str(args.seed)]
    if not args.full:
        argv.append("--reduced")
    S.main(argv)


if __name__ == "__main__":
    main()
