"""Quickstart: the BrSGD aggregation rule in 40 lines.

Builds a worker-gradient matrix G for a toy strongly convex problem,
corrupts 25% of the rows with the paper's Gradient Scale attack, and
shows that  mean() is destroyed while  brsgd() recovers the honest mean.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators, threat

m, d = 20, 1_000
rng = np.random.default_rng(0)

# honest workers: gradient = true_grad + noise
true_grad = rng.normal(size=d).astype("f4")
G = jnp.asarray(true_grad[None] + 0.1 * rng.normal(size=(m, d)).astype("f4"))

# the paper's Gradient Scale attack on 25% of the workers
bcfg = ByzantineConfig(aggregator="brsgd", attack="scale", alpha=0.25,
                       scale_factor=1e10)
G_attacked = threat.apply_dense(G, jax.random.PRNGKey(0), bcfg)

naive = aggregators.mean(G_attacked)
robust, state = aggregators.brsgd(G_attacked, bcfg, return_state=True)

err = lambda v: float(jnp.linalg.norm(v - jnp.asarray(true_grad)))
print(f"workers m={m}, dims d={d}, byzantine={int(0.25 * m)}")
print(f"naive mean error : {err(naive):.3e}   <- destroyed by one attack")
print(f"brsgd error      : {err(robust):.3e}")
print(f"selected workers : {np.flatnonzero(np.asarray(state.selected)).tolist()}")
print(f"l1-filter kept   : {int(state.c1.sum())}, score-filter kept: "
      f"{int(state.c2.sum())} (beta={bcfg.beta})")
assert err(robust) < 1.0 < err(naive)
print("OK: BrSGD recovered the honest gradient.")
