"""Paper Fig 3: convergence (accuracy vs step) per aggregator under each
attack at alpha=25%.  Prints the curves as CSV for plotting."""
from __future__ import annotations

import sys

from .common import train_lenet

ATTACKS = ["gaussian", "negation", "scale", "label_flip"]
AGGS = ["brsgd", "median", "mean"]


def main(steps: int = 60):
    print("aggregator,attack,step,accuracy")
    _, base_curve = train_lenet("mean", "none", 0.0, steps=steps)
    for s, a in base_curve:
        print(f"mean,none,{s},{a:.3f}")
    for agg in AGGS:
        for attack in ATTACKS:
            _, curve = train_lenet(agg, attack, 0.25, steps=steps)
            for s, a in curve:
                print(f"{agg},{attack},{s},{a:.3f}", flush=True)
    # convergence claim: brsgd reaches baseline-level accuracy at the end
    return 0


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    sys.exit(main(steps))
