"""Run every benchmark (one per paper table/figure + this build's
roofline report).  ``python -m benchmarks.run [--quick]``."""
from __future__ import annotations

import sys
import time


def main() -> int:
    quick = "--quick" in sys.argv
    steps = 30 if quick else 60
    rc = 0
    from . import (ablation, agg_cost, fig3, rate, robustness,
                   roofline_report, table1)
    for name, fn in [
        ("table1 (acc x attack x alpha x aggregator)",
         lambda: table1.main(steps)),
        ("fig3 (convergence curves)", lambda: fig3.main(steps)),
        ("agg_cost (O(md) complexity claim)", agg_cost.main),
        ("rate (Theorem 1 statistical rate)", rate.main),
        ("ablation (beta / threshold contributions)", ablation.main),
        ("robustness (6 attacks x 6 aggregators, ALIE/IPM)",
         robustness.main),
        ("roofline (dry-run derived)", roofline_report.main),
    ]:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            r = fn() or 0
        except Exception as e:  # keep the harness going, report at the end
            print(f"ERROR in {name}: {type(e).__name__}: {e}")
            r = 1
        rc = rc or r
        print(f"===== done in {time.time() - t0:.1f}s (rc={r}) =====")
    return rc


if __name__ == "__main__":
    sys.exit(main())
