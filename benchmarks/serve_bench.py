"""Serving benchmark: continuous-batching latency/throughput vs batch
size, plus hot-swap stall time.

For each slot count in ``BATCHES`` the scheduler serves a saturating
synthetic request stream; p50/p99 per-token latency are percentiles over
decode-step wall times (every live slot emits one token per step —
serving/telemetry.py) after a warmup run absorbs the compiles.  The swap
section times one forced checkpoint hot-swap under live decode and
asserts the decode step never recompiled.

Writes ``BENCH_serve.json`` at the repo root, stamped with the same
backend/jax-version/git-rev provenance as BENCH_agg.json and validated
by ``benchmarks/check_bench.py`` in CI:

  PYTHONPATH=src python benchmarks/serve_bench.py [--arch qwen3-0.6b]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import tempfile

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models import params as PM
from repro.models import transformer as TF
from repro.serving import HotSwapper, ServeLoop
from repro.serving.telemetry import ServeMetrics, _percentile

BATCHES = [1, 4, 16]
PROMPT_LEN, GEN = 16, 32
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_serve.json")
SERVE_SCHEMA = 1


def bench_meta() -> dict:
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=REPO, capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    return {"backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "git_rev": rev,
            "date": datetime.date.today().isoformat()}


def _submit_stream(loop, rng, n, vocab):
    for _ in range(n):
        loop.submit(rng.randint(0, vocab, size=PROMPT_LEN), max_new=GEN)


def bench_batch(cfg, params, max_batch: int, seed: int = 0) -> dict:
    loop = ServeLoop(cfg, max_batch, PROMPT_LEN + GEN, params=params)
    rng = np.random.RandomState(seed)
    _submit_stream(loop, rng, max_batch, cfg.vocab)     # warmup: compiles
    loop.run()
    loop.metrics = ServeMetrics()                       # measured run
    n_req = 2 * max_batch
    _submit_stream(loop, rng, n_req, cfg.vocab)
    loop.run()
    snap = loop.metrics.snapshot()
    lat = sorted(loop.metrics.step_lat_s)
    return {"batch": max_batch,
            "requests": n_req,
            "steps": len(lat),
            "p50_ms": _percentile(lat, 0.50) * 1e3,
            "p99_ms": _percentile(lat, 0.99) * 1e3,
            "tokens_per_s": snap["tokens_per_s"]}


def bench_swap(cfg, params, seed: int = 0) -> dict:
    """One forced hot swap under live decode; stall = restore+flip time."""
    d = tempfile.mkdtemp(prefix="repro_swapbench_")
    ckpt.save(d, params, step=1)
    swapper = HotSwapper(d, like=params)
    loop = ServeLoop(cfg, 4, PROMPT_LEN + GEN, swapper=swapper)
    rng = np.random.RandomState(seed)
    _submit_stream(loop, rng, 8, cfg.vocab)

    def on_step(lp, s):
        if s == 4:
            ckpt.save(d, jax.tree.map(lambda x: x * 1.01, params), step=2)

    done = loop.run(on_step=on_step)
    assert len(done) == 8 and swapper.swap_count >= 1
    compiles = loop.decode_compiles()
    assert compiles == 1, f"decode recompiled across the swap: {compiles}"
    return {"swaps": swapper.swap_count,
            "stall_ms": swapper.swap_stall_s * 1e3,
            "decode_compiles": compiles}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = PM.init_params(TF.param_defs(cfg), jax.random.PRNGKey(0))
    rows = []
    for b in BATCHES:
        row = bench_batch(cfg, params, b)
        rows.append(row)
        print(f"batch={b:3d} p50={row['p50_ms']:.2f}ms "
              f"p99={row['p99_ms']:.2f}ms "
              f"tokens/s={row['tokens_per_s']:.0f}")
    swap = bench_swap(cfg, params)
    print(f"swap: {swap['swaps']} swap(s), stall={swap['stall_ms']:.1f}ms, "
          f"{swap['decode_compiles']} decode compile")
    bench = {"schema": SERVE_SCHEMA, "kind": "serve", "meta": bench_meta(),
             "arch": cfg.name, "prompt_len": PROMPT_LEN, "gen": GEN,
             "rows": rows, "swap": swap}
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {args.out}")
    return bench


if __name__ == "__main__":
    main()
