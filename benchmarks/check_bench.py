"""Validate the committed ``BENCH_agg.json`` + ``BENCH_contracts.json``
+ ``BENCH_robustness.csv`` + ``BENCH_serve.json`` + ``BENCH_faults.json``
schemas and metadata.

Import-check tier: no timing, no devices — safe to run in CI on every
PR (.github/workflows/ci.yml).  Guards the perf-trajectory contract:
every benchmark file must carry the provenance stamp (backend /
jax-version / git-rev) that makes cross-PR ``agg_cost.py --compare``
and ``lint`` bytes-envelope runs meaningful, and every registered
aggregator must be covered (local timing rows; per-layout contract
cases; per-quorum robustness rows) so a registry addition without a
regeneration fails loudly.

Usage: ``PYTHONPATH=src python benchmarks/check_bench.py [FILE ...]``
No arguments validates all committed files.  A ``.csv`` file is
checked as the robustness matrix; JSON files dispatch on their
``"kind"`` stamp (``"contracts"``, ``"serve"``, ``"faults"``, else the
agg timing schema).  Exit code 0 when every file is valid, 1 with a
message per violation otherwise.
"""
from __future__ import annotations

import json
import math
import os
import sys

# timing-row layouts (BENCH_agg.json): "elastic" is the masked
# quorum-round aggregate_local — an execution mode of the local layout,
# so the contract matrix does NOT owe it separate (agg × layout) cases
LAYOUTS = {"local", "gather", "a2a", "blocked", "elastic"}
CONTRACT_LAYOUTS = {"local", "gather", "a2a", "blocked"}
CONTRACT_MESHES = {"flat", "dm", "none"}
META_KEYS = ("backend", "jax_version", "git_rev", "date")
ROW_KEYS = ("aggregator", "layout", "m", "d", "us_per_call")
CASE_KEYS = ("aggregator", "layout", "mesh", "scope", "counts", "bytes",
             "collective_bytes")
SCHEMA = 2
CONTRACTS_SCHEMA = 1
SERVE_SCHEMA = 1
SERVE_BATCHES = {1, 4, 16}
SERVE_ROW_KEYS = ("batch", "requests", "steps", "p50_ms", "p99_ms",
                  "tokens_per_s")
SERVE_SWAP_KEYS = ("swaps", "stall_ms", "decode_compiles")
FAULTS_SCHEMA = 1
# the acceptance schedule must exercise at least these fault kinds
# concurrently with an active byzantine attack (ISSUE: host crash +
# honest NaN burst + corrupt checkpoint)
FAULTS_REQUIRED_KINDS = {"host_crash", "nan_burst", "corrupt_ckpt"}
FAULTS_TRAIN_KEYS = ("params_finite", "loss_clean", "loss_faulted",
                     "loss_ratio", "zero_recompiles", "mttr")
FAULTS_SERVE_KEYS = ("requests", "completed", "requeues",
                     "quarantined_ckpts", "swaps", "decode_compiles")


def check(path: str) -> list:
    errors = []
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if bench.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA}, got {bench.get('schema')!r}")
    meta = bench.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing 'meta' provenance stamp")
    else:
        for k in META_KEYS:
            if not isinstance(meta.get(k), str) or not meta.get(k):
                errors.append(f"meta.{k} must be a non-empty string")

    rows = bench.get("rows")
    if not isinstance(rows, list) or not rows:
        return errors + ["'rows' must be a non-empty list"]
    for i, r in enumerate(rows):
        ctx = f"rows[{i}]"
        if not isinstance(r, dict) or set(ROW_KEYS) - set(r):
            errors.append(f"{ctx}: needs keys {ROW_KEYS}")
            continue
        if r["layout"] not in LAYOUTS:
            errors.append(f"{ctx}: unknown layout {r['layout']!r}")
        if not (isinstance(r["m"], int) and r["m"] > 0
                and isinstance(r["d"], int) and r["d"] > 0):
            errors.append(f"{ctx}: m/d must be positive ints")
        us = r["us_per_call"]
        if not (isinstance(us, (int, float)) and math.isfinite(us)
                and us > 0):
            errors.append(f"{ctx}: us_per_call must be positive finite")

    # derived sections: scaling-law fits and the elastic-overhead table
    # feed the perf-trajectory compare — garbage there (NaN exponents
    # from a degenerate geomean, zero overheads) silently corrupts every
    # later --compare, so reject it at commit time
    fits = bench.get("fits")
    if not isinstance(fits, dict) or not fits:
        errors.append("'fits' must be a non-empty dict of scaling fits")
    else:
        for agg, fit in sorted(fits.items()):
            exps = [fit.get("m_exp"), fit.get("d_exp")] \
                if isinstance(fit, dict) else [None]
            if not all(isinstance(v, (int, float)) and math.isfinite(v)
                       for v in exps):
                errors.append(f"fits[{agg}]: m_exp/d_exp must be finite "
                              f"floats, got {fit!r}")
    eo = bench.get("elastic_overhead")
    if not isinstance(eo, dict) or not eo:
        errors.append("'elastic_overhead' must be a non-empty dict")
    else:
        for agg, v in sorted(eo.items()):
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v > 0):
                errors.append(f"elastic_overhead[{agg}]: must be positive "
                              f"finite, got {v!r}")

    # every registered aggregator has local rows (needs PYTHONPATH=src;
    # skipped gracefully when repro isn't importable, e.g. bare checkout)
    try:
        from repro.core import engine
    except ImportError:
        engine = None
    if engine is not None:
        for layout in ("local", "elastic"):
            have = {r["aggregator"] for r in rows
                    if isinstance(r, dict) and r.get("layout") == layout}
            missing = set(engine.registered()) - have
            if missing:
                errors.append(
                    f"registered aggregators without {layout} rows: "
                    f"{sorted(missing)} — re-run benchmarks/agg_cost.py")

    # the cost-model drift gate: measured rows must keep the analytic
    # shape (within 2x after per-group calibration) and the layout
    # planner must pick within the acceptance band of the best measured
    # layout (DESIGN.md §Cost) — a committed bench that fails either is
    # a perf regression or a broken measurement, not a re-anchor
    try:
        from repro.analysis import costmodel
    except ImportError:
        costmodel = None
    if costmodel is not None:
        errors += costmodel.validate_rows(bench)
        errors += costmodel.validate_pick(bench)
    return errors


def _registered_aggregators():
    """Registry names, or None when repro isn't importable (bare
    checkout without PYTHONPATH=src) — coverage checks then skip."""
    try:
        from repro.core import engine
    except ImportError:
        return None
    return set(engine.registered())


def check_contracts(path: str) -> list:
    """Validate a BENCH_contracts.json (written by ``python -m
    repro.launch.lint --record``): provenance stamp, per-case schema,
    no unknown aggregator/layout/mesh names, full (aggregator × layout)
    coverage, finite non-negative byte counts."""
    errors = []
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if bench.get("schema") != CONTRACTS_SCHEMA:
        errors.append(f"contracts schema must be {CONTRACTS_SCHEMA}, "
                      f"got {bench.get('schema')!r}")
    if bench.get("kind") != "contracts":
        errors.append("missing 'kind': 'contracts' stamp")
    meta = bench.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing 'meta' provenance stamp")
    else:
        for k in META_KEYS:
            if not isinstance(meta.get(k), str) or not meta.get(k):
                errors.append(f"meta.{k} must be a non-empty string")

    cases = bench.get("cases")
    if not isinstance(cases, list) or not cases:
        return errors + ["'cases' must be a non-empty list"]
    known = _registered_aggregators()
    seen = set()
    for i, c in enumerate(cases):
        ctx = f"cases[{i}]"
        if not isinstance(c, dict) or set(CASE_KEYS) - set(c):
            errors.append(f"{ctx}: needs keys {CASE_KEYS}")
            continue
        ctx = f"cases[{i}] ({c['aggregator']}/{c['layout']}/{c['mesh']})"
        if known is not None and c["aggregator"] not in known:
            errors.append(f"{ctx}: unknown aggregator — registry has "
                          f"{sorted(known)}")
        if c["layout"] not in CONTRACT_LAYOUTS:
            errors.append(f"{ctx}: unknown layout {c['layout']!r}")
        if c["mesh"] not in CONTRACT_MESHES:
            errors.append(f"{ctx}: unknown mesh {c['mesh']!r}")
        if (c["layout"] == "local") != (c["mesh"] == "none"):
            errors.append(f"{ctx}: the local layout (and only it) is "
                          f"meshless")
        nb = c["collective_bytes"]
        vals = [nb, *c["bytes"].values(), *c["counts"].values()] \
            if isinstance(c["bytes"], dict) and isinstance(c["counts"], dict) \
            else [nb]
        if not all(isinstance(v, (int, float)) and math.isfinite(v)
                   and v >= 0 for v in vals):
            errors.append(f"{ctx}: counts/bytes must be finite and "
                          f"non-negative")
        seen.add((c["aggregator"], c["layout"]))
    if known is not None:
        missing = {(a, l) for a in known for l in CONTRACT_LAYOUTS} - seen
        if missing:
            errors.append(
                f"missing (aggregator × layout) contract coverage: "
                f"{sorted(missing)} — re-run "
                f"`python -m repro.launch.lint --all --record`")

    # analytic cross-check: every extracted case must match the cost
    # model's predicted collective counts/bytes EXACTLY — the contract
    # formulas and the extractor keep each other honest
    try:
        from repro.analysis import costmodel
    except ImportError:
        costmodel = None
    if costmodel is not None and not errors:
        errors += costmodel.validate_contracts(bench)
    return errors


def check_robustness(path: str) -> list:
    """Validate a BENCH_robustness.csv (written by
    ``benchmarks/robustness.py``): quorum column first, every
    registered aggregator covered at every quorum, the fixed-m quorum
    plus at least one elastic (q < m) quorum present, finite-or-``inf``
    error cells, and the recorded claim line saying PASS."""
    errors = []
    try:
        with open(path) as f:
            raw = f.read().splitlines()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    comments = [l for l in raw if l.startswith("#")]
    body = [l for l in raw if l.strip() and not l.startswith("#")]
    if not body or not body[0].startswith("quorum,aggregator,"):
        return errors + ["header must be 'quorum,aggregator,<attacks>' "
                         "— re-run benchmarks/robustness.py"]
    attacks = body[0].split(",")[2:]
    if not attacks:
        errors.append("no attack columns in the header")
    per_quorum: dict = {}
    for i, line in enumerate(body[1:]):
        ctx = f"row {i + 1} ({line.split(',')[0:2]})"
        cells = line.split(",")
        if len(cells) != 2 + len(attacks):
            errors.append(f"{ctx}: expected {2 + len(attacks)} cells, "
                          f"got {len(cells)}")
            continue
        try:
            q = int(cells[0])
        except ValueError:
            errors.append(f"{ctx}: quorum must be an int, got {cells[0]!r}")
            continue
        if q <= 0:
            errors.append(f"{ctx}: quorum must be positive")
        per_quorum.setdefault(q, set()).add(cells[1])
        for a, v in zip(attacks, cells[2:]):
            try:
                x = float(v)
            except ValueError:
                errors.append(f"{ctx}: {a} cell {v!r} is not a float")
                continue
            if math.isnan(x) or x < 0:
                errors.append(f"{ctx}: {a} error must be >= 0 and not NaN")
    if not per_quorum:
        return errors + ["no data rows"]
    qmax = max(per_quorum)
    if not any(q < qmax for q in per_quorum):
        errors.append(f"only the fixed-m quorum {qmax} is present — the "
                      f"matrix must include at least one elastic q < m "
                      f"sweep (re-run benchmarks/robustness.py)")
    known = _registered_aggregators()
    if known is not None:
        for q, aggs in sorted(per_quorum.items()):
            missing = known - aggs
            if missing:
                errors.append(f"quorum {q}: registered aggregators "
                              f"without rows: {sorted(missing)}")
    claim = [l for l in comments if "CLAIM" in l]
    if not claim:
        errors.append("missing '# CLAIM ...' line")
    elif "PASS" not in claim[-1]:
        errors.append(f"recorded claim is not PASS: {claim[-1]!r}")
    return errors


def check_serve(path: str) -> list:
    """Validate a BENCH_serve.json (written by ``benchmarks/
    serve_bench.py``): provenance stamp, latency/throughput rows
    covering batch sizes {1, 4, 16} with finite positive values and
    p50 <= p99, and a swap section proving at least one hot swap
    completed with a single decode compile."""
    errors = []
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if bench.get("schema") != SERVE_SCHEMA:
        errors.append(f"serve schema must be {SERVE_SCHEMA}, "
                      f"got {bench.get('schema')!r}")
    if bench.get("kind") != "serve":
        errors.append("missing 'kind': 'serve' stamp")
    meta = bench.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing 'meta' provenance stamp")
    else:
        for k in META_KEYS:
            if not isinstance(meta.get(k), str) or not meta.get(k):
                errors.append(f"meta.{k} must be a non-empty string")

    rows = bench.get("rows")
    if not isinstance(rows, list) or not rows:
        return errors + ["'rows' must be a non-empty list"]
    batches = set()
    for i, r in enumerate(rows):
        ctx = f"rows[{i}]"
        if not isinstance(r, dict) or set(SERVE_ROW_KEYS) - set(r):
            errors.append(f"{ctx}: needs keys {SERVE_ROW_KEYS}")
            continue
        ctx = f"rows[{i}] (batch={r['batch']})"
        if not (isinstance(r["batch"], int) and r["batch"] > 0):
            errors.append(f"{ctx}: batch must be a positive int")
        else:
            batches.add(r["batch"])
        for k in ("requests", "steps"):
            if not (isinstance(r[k], int) and r[k] > 0):
                errors.append(f"{ctx}: {k} must be a positive int")
        for k in ("p50_ms", "p99_ms", "tokens_per_s"):
            v = r[k]
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v > 0):
                errors.append(f"{ctx}: {k} must be positive finite")
        if (isinstance(r["p50_ms"], (int, float))
                and isinstance(r["p99_ms"], (int, float))
                and r["p50_ms"] > r["p99_ms"]):
            errors.append(f"{ctx}: p50_ms > p99_ms")
    missing = SERVE_BATCHES - batches
    if missing:
        errors.append(f"missing batch sizes {sorted(missing)} — re-run "
                      f"benchmarks/serve_bench.py")

    swap = bench.get("swap")
    if not isinstance(swap, dict) or set(SERVE_SWAP_KEYS) - set(swap):
        return errors + [f"'swap' must be a dict with keys "
                         f"{SERVE_SWAP_KEYS}"]
    if not (isinstance(swap["swaps"], int) and swap["swaps"] >= 1):
        errors.append("swap.swaps must be an int >= 1 — the bench must "
                      "exercise a live hot swap")
    st = swap["stall_ms"]
    if not (isinstance(st, (int, float)) and math.isfinite(st)
            and st >= 0):
        errors.append("swap.stall_ms must be finite and non-negative")
    if swap["decode_compiles"] != 1:
        errors.append(f"swap.decode_compiles must be 1 (zero-recompile "
                      f"hot swap), got {swap['decode_compiles']!r}")
    return errors


def check_faults(path: str) -> list:
    """Validate a BENCH_faults.json (written by ``benchmarks/chaos.py``):
    provenance stamp, the required fault kinds scheduled under a real
    (non-``none``) byzantine attack, finite params with a final-loss
    ratio <= 2x the fault-free control, zero train-step recompiles, a
    serve phase where every request completed (requeues allowed — drops
    are not) with at least one quarantined checkpoint and a single
    decode compile, and a recorded PASS claim."""
    errors = []
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if bench.get("schema") != FAULTS_SCHEMA:
        errors.append(f"faults schema must be {FAULTS_SCHEMA}, "
                      f"got {bench.get('schema')!r}")
    if bench.get("kind") != "faults":
        errors.append("missing 'kind': 'faults' stamp")
    meta = bench.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing 'meta' provenance stamp")
    else:
        for k in META_KEYS:
            if not isinstance(meta.get(k), str) or not meta.get(k):
                errors.append(f"meta.{k} must be a non-empty string")
    if not bench.get("attack") or bench.get("attack") == "none":
        errors.append("the chaos run must hold under an ACTIVE attack — "
                      "'attack' is missing or 'none'")

    plan = bench.get("plan")
    if not isinstance(plan, list) or not plan:
        errors.append("'plan' must be a non-empty fault schedule")
    else:
        kinds = {r.get("fault") for r in plan if isinstance(r, dict)}
        missing = FAULTS_REQUIRED_KINDS - kinds
        if missing:
            errors.append(f"plan missing required fault kinds "
                          f"{sorted(missing)} — re-run benchmarks/chaos.py")

    train = bench.get("train")
    if not isinstance(train, dict) or set(FAULTS_TRAIN_KEYS) - set(train):
        errors.append(f"'train' must be a dict with keys "
                      f"{FAULTS_TRAIN_KEYS}")
    else:
        if train["params_finite"] is not True:
            errors.append("train.params_finite must be true")
        if train["zero_recompiles"] is not True:
            errors.append("train.zero_recompiles must be true — fault "
                          "churn must not retrace the step")
        ratio = train["loss_ratio"]
        if not (isinstance(ratio, (int, float)) and math.isfinite(ratio)
                and 0 < ratio <= 2.0):
            errors.append(f"train.loss_ratio must be finite and <= 2.0 "
                          f"(faulted vs fault-free), got {ratio!r}")
        mttr = train["mttr"]
        if not isinstance(mttr, list) or not mttr:
            errors.append("train.mttr must be a non-empty list")
        else:
            for r in mttr:
                rec = r.get("steps_to_recover") if isinstance(r, dict) \
                    else None
                if not (isinstance(rec, int) and rec >= 0):
                    errors.append(f"train.mttr: {r!r} never recovered "
                                  f"(steps_to_recover must be an int >= 0)")

    serve = bench.get("serve")
    if not isinstance(serve, dict) or set(FAULTS_SERVE_KEYS) - set(serve):
        errors.append(f"'serve' must be a dict with keys "
                      f"{FAULTS_SERVE_KEYS}")
    else:
        if serve["completed"] != serve["requests"]:
            errors.append(f"serve: {serve['completed']}/"
                          f"{serve['requests']} requests completed — "
                          f"faults must not drop requests")
        if not (isinstance(serve["requeues"], int)
                and serve["requeues"] >= 1):
            errors.append("serve.requeues must be >= 1 — the wedged-slot "
                          "fault must exercise the watchdog")
        if not (isinstance(serve["quarantined_ckpts"], int)
                and serve["quarantined_ckpts"] >= 1):
            errors.append("serve.quarantined_ckpts must be >= 1 — the "
                          "corrupt publish must be quarantined")
        if serve["decode_compiles"] != 1:
            errors.append(f"serve.decode_compiles must be 1, got "
                          f"{serve['decode_compiles']!r}")

    if bench.get("claim") != "PASS":
        errors.append(f"recorded claim is not PASS: {bench.get('claim')!r}")
    return errors


def _check_any(path: str) -> list:
    """Dispatch: ``.csv`` is the robustness matrix; JSON files on the
    ``kind`` stamp."""
    if path.endswith(".csv"):
        return check_robustness(path)
    try:
        with open(path) as f:
            kind = json.load(f).get("kind")
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if kind == "contracts":
        return check_contracts(path)
    if kind == "serve":
        return check_serve(path)
    if kind == "faults":
        return check_faults(path)
    return check(path)


def main(argv) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv[1:] or [os.path.join(root, "BENCH_agg.json"),
                         os.path.join(root, "BENCH_contracts.json"),
                         os.path.join(root, "BENCH_robustness.csv"),
                         os.path.join(root, "BENCH_serve.json"),
                         os.path.join(root, "BENCH_faults.json")]
    errors = []
    for path in paths:
        errs = _check_any(path)
        errors += [f"{os.path.basename(path)}: {e}" for e in errs]
        if not errs:
            print(f"check_bench: {os.path.normpath(path)} OK")
    for e in errors:
        print(f"check_bench: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
