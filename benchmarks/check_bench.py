"""Validate the committed ``BENCH_agg.json`` schema + metadata.

Import-check tier: no timing, no devices — safe to run in CI on every
PR (.github/workflows/ci.yml).  Guards the perf-trajectory contract:
every benchmark file must carry the provenance stamp (backend /
jax-version / git-rev) that makes cross-PR ``agg_cost.py --compare``
runs meaningful, and every registered aggregator must have local-layout
rows so a registry addition without a benchmark regeneration fails
loudly.

Usage: ``PYTHONPATH=src python benchmarks/check_bench.py [BENCH_JSON]``
Exit code 0 on a valid file, 1 with a message per violation otherwise.
"""
from __future__ import annotations

import json
import math
import os
import sys

LAYOUTS = {"local", "gather", "a2a", "blocked"}
META_KEYS = ("backend", "jax_version", "git_rev", "date")
ROW_KEYS = ("aggregator", "layout", "m", "d", "us_per_call")
SCHEMA = 2


def check(path: str) -> list:
    errors = []
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if bench.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA}, got {bench.get('schema')!r}")
    meta = bench.get("meta")
    if not isinstance(meta, dict):
        errors.append("missing 'meta' provenance stamp")
    else:
        for k in META_KEYS:
            if not isinstance(meta.get(k), str) or not meta.get(k):
                errors.append(f"meta.{k} must be a non-empty string")

    rows = bench.get("rows")
    if not isinstance(rows, list) or not rows:
        return errors + ["'rows' must be a non-empty list"]
    for i, r in enumerate(rows):
        ctx = f"rows[{i}]"
        if not isinstance(r, dict) or set(ROW_KEYS) - set(r):
            errors.append(f"{ctx}: needs keys {ROW_KEYS}")
            continue
        if r["layout"] not in LAYOUTS:
            errors.append(f"{ctx}: unknown layout {r['layout']!r}")
        if not (isinstance(r["m"], int) and r["m"] > 0
                and isinstance(r["d"], int) and r["d"] > 0):
            errors.append(f"{ctx}: m/d must be positive ints")
        us = r["us_per_call"]
        if not (isinstance(us, (int, float)) and math.isfinite(us)
                and us > 0):
            errors.append(f"{ctx}: us_per_call must be positive finite")

    # every registered aggregator has local rows (needs PYTHONPATH=src;
    # skipped gracefully when repro isn't importable, e.g. bare checkout)
    try:
        from repro.core import engine
    except ImportError:
        engine = None
    if engine is not None:
        local = {r["aggregator"] for r in rows
                 if isinstance(r, dict) and r.get("layout") == "local"}
        missing = set(engine.registered()) - local
        if missing:
            errors.append(f"registered aggregators without local rows: "
                          f"{sorted(missing)} — re-run benchmarks/agg_cost.py")
    return errors


def main(argv) -> int:
    path = argv[1] if len(argv) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_agg.json")
    errors = check(path)
    for e in errors:
        print(f"check_bench: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench: {os.path.normpath(path)} OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
