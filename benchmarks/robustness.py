"""Extended robustness matrix (beyond the paper's Table 1): every
gradient attack registered in core.threat x every aggregator registered
in core.engine, on the strongly convex problem — including the
literature's subtler attacks (ALIE, IPM) and extra baselines (Krum,
multi-Krum, geometric median).

Reported: final ||w - w*|| (lower is better).  Structure expected:
  * brsgd / geomedian / multi_krum stay near the clean error under all
    attacks with alpha=0.25;
  * mean is destroyed by scale/negation and biased by alie/ipm.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators, engine, threat

D, STEPS, LR, M, N = 20, 150, 0.3, 20, 400
# every gradient-scope attack in the threat registry (data-scope specs
# like label_flip corrupt the pipeline, not G — nothing to do here), in
# the historical column order with any newly registered attack appended
_ORDER = ["gaussian", "negation", "scale", "sign_flip", "alie", "ipm"]
_GRAD = [n for n in threat.registered()
         if threat.get_spec(n).scope == "gradient"]
ATTACKS = ([a for a in _ORDER if a in _GRAD]
           + sorted(a for a in _GRAD if a not in _ORDER))
# every rule in the engine registry — brsgd first, the non-robust mean
# baseline last, so the matrix never silently drops a new aggregator
AGGS = ["brsgd"] + sorted(n for n in engine.registered()
                          if n not in ("brsgd", "mean")) + ["mean"]


def run(agg: str, attack: str, alpha: float = 0.25, seed: int = 0):
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=D).astype("f4") / np.sqrt(D)
    X = rng.normal(size=(M, N, D)).astype("f4")
    y = X @ w_star + 0.5 * rng.normal(size=(M, N)).astype("f4")
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    # per-attack strengths are explicit config fields with the paper's
    # defaults — no more attack_scale=1e10 special-casing by name
    bcfg = ByzantineConfig(aggregator=agg, attack=attack, alpha=alpha)

    @jax.jit
    def step(w, key):
        G = jax.vmap(lambda Xi, yi: Xi.T @ (Xi @ w - yi) / N)(Xj, yj)
        G = threat.apply_dense(G, key, bcfg)
        return w - LR * aggregators.aggregate(G, bcfg)

    w = jnp.zeros(D, jnp.float32)
    key = jax.random.PRNGKey(seed)
    for t in range(STEPS):
        w = step(w, jax.random.fold_in(key, t))
    e = float(jnp.linalg.norm(w - jnp.asarray(w_star)))
    return e if np.isfinite(e) else float("inf")


def main():
    clean = float(np.mean([run("mean", "none", 0.0, s) for s in range(2)]))
    print(f"# clean-mean error: {clean:.4f}")
    print("aggregator," + ",".join(ATTACKS))
    errs = {}
    for agg in AGGS:
        row = []
        for attack in ATTACKS:
            e = float(np.mean([run(agg, attack, seed=s) for s in range(2)]))
            errs[(agg, attack)] = e
            row.append("inf" if not np.isfinite(e) else f"{e:.4f}")
        print(f"{agg}," + ",".join(row), flush=True)
    worst_brsgd = max(errs[("brsgd", a)] for a in ATTACKS)
    mean_broken = any(not np.isfinite(errs[("mean", a)])
                      or errs[("mean", a)] > 10 * clean
                      for a in ("scale", "negation"))
    ok = worst_brsgd < 5 * clean + 0.1 and mean_broken
    print(f"# brsgd worst error {worst_brsgd:.4f} vs clean {clean:.4f}")
    print(f"# CLAIM robust to all {len(ATTACKS)} registered attacks "
          f"incl. ALIE/IPM: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
