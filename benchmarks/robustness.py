"""Extended robustness matrix (beyond the paper's Table 1): every
gradient attack registered in core.threat x every aggregator registered
in core.engine, on the strongly convex problem — including the
literature's subtler attacks (ALIE, IPM), the timing-scope ``stall``
attack, and extra baselines (Krum, multi-Krum, geometric median).

Each row carries a ``quorum`` column: q = m is the classic fixed-m
synchronous round (bit-compatible with the pre-elastic matrix), while
q < m runs the elastic path — per-step active set from an
ArrivalSchedule, masked apply_dense and masked aggregate_local — so the
claim is checked where the paper's guarantee actually has to hold:
over the ACTIVE set, with n_byzantine = floor(alpha * q).

Reported: final ||w - w*|| (lower is better).  Structure expected:
  * brsgd / geomedian / multi_krum stay near the clean error under all
    attacks with alpha=0.25, at q = m AND q = 0.75m;
  * mean is destroyed by scale/negation and biased by alie/ipm;
  * under stall the byzantine workers simply never arrive, so every
    rule (mean included) lands near the clean error.

Writes BENCH_robustness.csv (schema checked by check_bench.py).
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators, engine, threat
from repro.data.pipeline import ArrivalSchedule

D, STEPS, LR, M, N = 20, 150, 0.3, 20, 400
# the sweep's quorum column: the fixed-m round plus the two elastic
# operating points the acceptance gate cares about (0.75m, 0.5m)
QUORUMS = [M, int(0.75 * M), M // 2]
# every gradient-scope attack in the threat registry (data-scope specs
# like label_flip corrupt the pipeline, not G — nothing to do here), in
# the historical column order with any newly registered attack appended;
# timing-scope attacks (stall) ride the ArrivalSchedule instead of G
_ORDER = ["gaussian", "negation", "scale", "sign_flip", "alie", "ipm"]
_GRAD = [n for n in threat.registered()
         if threat.get_spec(n).scope == "gradient"]
_TIMING = sorted(n for n in threat.registered()
                 if threat.get_spec(n).scope == "timing")
ATTACKS = ([a for a in _ORDER if a in _GRAD]
           + sorted(a for a in _GRAD if a not in _ORDER)
           + _TIMING)
# every rule in the engine registry — brsgd first, the non-robust mean
# baseline last, so the matrix never silently drops a new aggregator
AGGS = ["brsgd"] + sorted(n for n in engine.registered()
                          if n not in ("brsgd", "mean")) + ["mean"]
CSV_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_robustness.csv")


def run(agg: str, attack: str, alpha: float = 0.25, seed: int = 0,
        quorum: int = M):
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=D).astype("f4") / np.sqrt(D)
    X = rng.normal(size=(M, N, D)).astype("f4")
    y = X @ w_star + 0.5 * rng.normal(size=(M, N)).astype("f4")
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    # per-attack strengths are explicit config fields with the paper's
    # defaults — no more attack_scale=1e10 special-casing by name
    timing = (attack != "none"
              and threat.get_spec(attack).scope == "timing")
    elastic = quorum < M or timing
    if not elastic:
        # fixed-m synchronous round: the pre-elastic path, untouched
        bcfg = ByzantineConfig(aggregator=agg, attack=attack, alpha=alpha)

        @jax.jit
        def step(w, key):
            G = jax.vmap(lambda Xi, yi: Xi.T @ (Xi @ w - yi) / N)(Xj, yj)
            G = threat.apply_dense(G, key, bcfg)
            return w - LR * aggregators.aggregate(G, bcfg)

        w = jnp.zeros(D, jnp.float32)
        key = jax.random.PRNGKey(seed)
        for t in range(STEPS):
            w = step(w, jax.random.fold_in(key, t))
    else:
        # elastic round: quorum-of-m active set per step, masked
        # corruption + masked aggregation (the active mask is a traced
        # arg — ONE compile serves every step)
        bcfg = ByzantineConfig(aggregator=agg, attack=attack, alpha=alpha,
                               max_m=M, quorum=quorum)
        sched = ArrivalSchedule(M, quorum, byz=bcfg, seed=seed)

        @jax.jit
        def step(w, key, act):
            G = jax.vmap(lambda Xi, yi: Xi.T @ (Xi @ w - yi) / N)(Xj, yj)
            G = threat.apply_dense(G, key, bcfg, active=act)
            return w - LR * engine.aggregate_local(G, bcfg, valid=act)

        w = jnp.zeros(D, jnp.float32)
        key = jax.random.PRNGKey(seed)
        for t in range(STEPS):
            act = jnp.asarray(sched.active(t))
            w = step(w, jax.random.fold_in(key, t), act)
    e = float(jnp.linalg.norm(w - jnp.asarray(w_star)))
    return e if np.isfinite(e) else float("inf")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=CSV_PATH,
                    help="CSV output path (default: repo BENCH file)")
    args = ap.parse_args(argv)
    clean = float(np.mean([run("mean", "none", 0.0, s) for s in range(2)]))
    lines = [f"# clean-mean error: {clean:.4f}",
             "quorum,aggregator," + ",".join(ATTACKS)]
    print("\n".join(lines), flush=True)
    errs = {}
    for q in QUORUMS:
        for agg in AGGS:
            row = []
            for attack in ATTACKS:
                e = float(np.mean([run(agg, attack, seed=s, quorum=q)
                                   for s in range(2)]))
                errs[(q, agg, attack)] = e
                row.append("inf" if not np.isfinite(e) else f"{e:.4f}")
            line = f"{q},{agg}," + ",".join(row)
            lines.append(line)
            print(line, flush=True)
    # the claim must hold at the fixed-m round AND at quorum 0.75m —
    # dropping a quarter of the workers must not cost robustness
    claim_qs = [M, int(0.75 * M)]
    worst_brsgd = max(errs[(q, "brsgd", a)]
                      for q in claim_qs for a in ATTACKS)
    mean_broken = any(not np.isfinite(errs[(M, "mean", a)])
                      or errs[(M, "mean", a)] > 10 * clean
                      for a in ("scale", "negation"))
    ok = worst_brsgd < 5 * clean + 0.1 and mean_broken
    tail = [f"# brsgd worst error {worst_brsgd:.4f} vs clean {clean:.4f} "
            f"(over quorums {claim_qs})",
            f"# CLAIM robust to all {len(ATTACKS)} registered attacks "
            f"incl. ALIE/IPM/stall at q=m and q=0.75m: "
            f"{'PASS' if ok else 'FAIL'}"]
    lines += tail
    print("\n".join(tail))
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
