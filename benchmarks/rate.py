"""Theorem 1 statistical rate: ||w_T - w*|| = O(1/sqrt(n) + 1/sqrt(nm))
for strongly convex losses, robust to alpha < 1/2 Byzantine workers.

Setup: linear regression (strongly convex quadratic population loss)
with known w*.  Each worker holds n i.i.d. samples; we run BrSGD to
convergence and measure ||w_T - w*||_2 as a function of n and m, under
a scale attack at alpha=0.2.  The claim verified:
  * error decreases ~ 1/sqrt(n) as n grows (fixed m),
  * error at (n, m) tracks C1/sqrt(n) + C2/sqrt(nm),
  * error is far below the naive-mean error under the same attack.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators, threat

D = 20
STEPS = 150
LR = 0.3


def run(m: int, n: int, aggregator: str, alpha: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=D).astype("f4") / np.sqrt(D)
    X = rng.normal(size=(m, n, D)).astype("f4")
    y = X @ w_star + 0.5 * rng.normal(size=(m, n)).astype("f4")
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    bcfg = ByzantineConfig(aggregator=aggregator, attack="scale",
                           alpha=alpha, scale_factor=50.0)

    @jax.jit
    def step(w, key):
        def worker_grad(Xi, yi):
            r = Xi @ w - yi
            return Xi.T @ r / n
        G = jax.vmap(worker_grad)(Xj, yj)                    # [m, D]
        G = threat.apply_dense(G, key, bcfg)
        g = aggregators.aggregate(G, bcfg)
        return w - LR * g

    w = jnp.zeros(D, jnp.float32)
    key = jax.random.PRNGKey(seed)
    for t in range(STEPS):
        w = step(w, jax.random.fold_in(key, t))
    return float(jnp.linalg.norm(w - jnp.asarray(w_star)))


def main():
    print("m,n,aggregator,alpha,error")
    errs = {}
    for m in (10, 20):
        for n in (50, 200, 800, 3200):
            for agg, alpha in (("brsgd", 0.2), ("mean", 0.2), ("mean", 0.0)):
                # average 3 seeds
                e = float(np.mean([run(m, n, agg, alpha, seed=s)
                                   for s in range(3)]))
                errs[(m, n, agg, alpha)] = e
                print(f"{m},{n},{agg},{alpha},{e:.4f}", flush=True)

    # rate check: error(n) ~ n^-0.5 for brsgd (fixed m=20)
    ns = np.asarray([50, 200, 800, 3200], float)
    es = np.asarray([errs[(20, int(n), "brsgd", 0.2)] for n in ns])
    slope = np.polyfit(np.log(ns), np.log(es), 1)[0]
    print(f"# brsgd error ~ n^{slope:.2f}  (theory: -0.5)")
    ok_rate = -0.75 < slope < -0.25
    # robustness: brsgd under attack ~ clean-mean error; naive mean >> both
    e_brsgd = errs[(20, 800, "brsgd", 0.2)]
    e_clean = errs[(20, 800, "mean", 0.0)]
    e_mean = errs[(20, 800, "mean", 0.2)]
    print(f"# attack m=20 n=800: brsgd={e_brsgd:.4f} clean-mean={e_clean:.4f} "
          f"attacked-mean={e_mean:.4f}")
    mean_broken = (not np.isfinite(e_mean)) or e_mean > 3 * e_brsgd
    ok_rob = e_brsgd < 5 * e_clean + 0.05 and mean_broken
    print(f"# CLAIM order-optimal rate + robustness: "
          f"{'PASS' if (ok_rate and ok_rob) else 'FAIL'}")
    return 0 if (ok_rate and ok_rob) else 1


if __name__ == "__main__":
    sys.exit(main())
