"""Paper §1/§2 complexity claim: BrSGD aggregation is O(md); Krum is
O(m²(d + log m)); coordinate-wise median via sort is O(dm log m).

We time the jitted aggregators over a grid of (m, d), print the raw
wall-times, and fit the scaling exponents:
  * brsgd time ~ m^a d^b with a ~ 1, b ~ 1
  * krum grows ~ m² at fixed d (ratio check)
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators as A

from .common import time_fn

MS = [8, 16, 32, 64]
DS = [10_000, 40_000, 160_000]


def main():
    cfg = ByzantineConfig()
    kcfg = ByzantineConfig(aggregator="krum", alpha=0.25)
    fns = {
        "brsgd": jax.jit(lambda G: A.brsgd(G, cfg)),
        "median": jax.jit(lambda G: A.cwise_median(G)),
        "mean": jax.jit(lambda G: A.mean(G)),
        "krum": jax.jit(lambda G: A.krum(G, kcfg)),
    }
    rng = np.random.default_rng(0)
    times = {}
    print("aggregator,m,d,us_per_call")
    for m in MS:
        for d in DS:
            G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
            for name, fn in fns.items():
                us = time_fn(fn, G)
                times[(name, m, d)] = us
                print(f"{name},{m},{d},{us:.1f}", flush=True)

    # scaling fits (log-log least squares) for brsgd
    for name in ("brsgd", "mean"):
        xs, ys = [], []
        for (n, m, d), us in times.items():
            if n == name:
                xs.append([np.log(m), np.log(d), 1.0])
                ys.append(np.log(us))
        coef, *_ = np.linalg.lstsq(np.asarray(xs), np.asarray(ys), rcond=None)
        print(f"# {name} scaling: time ~ m^{coef[0]:.2f} * d^{coef[1]:.2f}")

    # krum m-scaling at fixed d (expect ~quadratic at large m)
    d = DS[-1]
    r64_16 = times[("krum", 64, d)] / times[("krum", 16, d)]
    rb = times[("brsgd", 64, d)] / times[("brsgd", 16, d)]
    print(f"# m 16->64 (4x): krum x{r64_16:.1f} (O(m^2)->16x), "
          f"brsgd x{rb:.1f} (O(m)->4x)")
    print(f"# CLAIM brsgd O(md): "
          f"{'PASS' if rb < (r64_16 + 1) / 2 or rb < 8 else 'FAIL'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
