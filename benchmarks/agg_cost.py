"""Paper §1/§2 complexity claim: BrSGD aggregation is O(md); Krum is
O(m²(d + log m)); coordinate-wise median via sort is O(dm log m).

We time every registered aggregator over a grid of (m, d) in the
``local`` layout, plus every (aggregator × {gather, a2a, blocked}) pair
under shard_map on an 8-device host mesh (subprocess — the main process
keeps the real device); ``blocked`` is the FSDP in-backward bucket path
(core.blocked) timed on one FSDP-sharded bucket.  The ``elastic``
layout rows time the masked quorum-round path
(``engine.aggregate_local(..., valid=act)`` at 75% active workers) on
the same (m, d) grid, so the elastic-vs-bulk overhead of the validity
masking is a committed, trackable number.  Raw wall-times are printed as CSV, the
scaling exponents are fitted (brsgd ~ m^a d^b with a ~ 1, b ~ 1; krum
grows ~ m² at fixed d), and every row is emitted to ``BENCH_agg.json``
at the repo root — stamped with backend/jax-version/git-rev metadata
(``benchmarks/check_bench.py`` validates the schema in CI) so the perf
trajectory of the fused statistics + select kernels is trackable across
PRs: ``--compare BASELINE`` prints per-(aggregator × layout) speedups
vs a previously committed file, and ``--compare OLD NEW`` diffs two
files without re-timing anything.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators as A, engine

from .common import time_fn

MS = [8, 16, 32, 64]
DS = [10_000, 40_000, 160_000]
D_DIST = 40_000          # distributed rows: one d, m = n_devices = 8
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_agg.json")
SCHEMA = 2               # 2: added the "meta" stamp (check_bench.py)


def bench_meta() -> dict:
    """Provenance stamp for one benchmark run — enough to interpret a
    row months later: numbers from different backends or jax versions
    are not comparable."""
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=REPO, capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    return {"backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "git_rev": rev,
            "date": datetime.date.today().isoformat()}

_DIST_SNIPPET = textwrap.dedent("""
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from repro.compat import P, shard_map
    from repro.configs.base import ByzantineConfig
    from repro.core.distributed import robust_aggregate
    from repro.launch.mesh import make_mesh

    m, d = 8, %d
    mesh = make_mesh((m,), ("data",))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))

    def bench(fn, *args, reps=5, warmup=2):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)

    from repro.core.blocked import _bucket_aggregate
    bspecs = {"g": P("data")}

    rows = []
    for name in %r:
        cfg = ByzantineConfig(aggregator=name, alpha=0.25)
        for layout in ("gather", "a2a"):
            @jax.jit
            @partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P())
            def agg(x):
                local = {"g": x.reshape(x.shape[1:])}
                return robust_aggregate(local, cfg, ("data",), layout)[0]["g"]
            us = bench(agg, g)
            rows.append({"aggregator": name, "layout": layout,
                         "m": m, "d": d, "us_per_call": us})

        # blocked scope: the FSDP in-backward bucket path, one bucket of
        # one [d] leaf sharded over the workers (output = local shard)
        @jax.jit
        @partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                 out_specs=P("data"))
        def bagg(x):
            local = {"g": x.reshape(x.shape[1:])}
            return _bucket_aggregate(local, bspecs, cfg, ("data",))[0]["g"]
        us = bench(bagg, g)
        rows.append({"aggregator": name, "layout": "blocked",
                     "m": m, "d": d, "us_per_call": us})
    print("JSON:" + json.dumps(rows))
""")


def _distributed_rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "")
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env["PYTHONPATH"]
    code = _DIST_SNIPPET % (D_DIST, sorted(A.AGGREGATORS))
    try:
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=1200)
    except (subprocess.TimeoutExpired, OSError) as e:
        # degrade to local-only rows rather than losing the whole run
        print(f"# distributed rows FAILED: {type(e).__name__}: {e}")
        return []
    if proc.returncode != 0:
        print(f"# distributed rows FAILED:\n{proc.stderr[-2000:]}")
        return []
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[5:])
    return []


def _geomean(ratios, label: str = "") -> float:
    """Geometric mean over the POSITIVE, finite ratios only.

    A zero or sub-timer-resolution timing used to flow straight into
    np.log as -inf/NaN and silently corrupt the printed speedup and the
    committed elastic_overhead fit; bad rows are now dropped with a
    warning (and an all-bad group returns NaN, which check_bench.py
    rejects loudly)."""
    arr = np.asarray(list(ratios), dtype=float)
    keep = np.isfinite(arr) & (arr > 0)
    if not np.all(keep):
        print(f"# WARNING: {label or 'geomean'}: dropped "
              f"{int((~keep).sum())}/{arr.size} non-positive or "
              "non-finite timing ratios")
    if not np.any(keep):
        return float("nan")
    return float(np.exp(np.mean(np.log(arr[keep]))))


def compare(base: dict, cur: dict) -> None:
    """Print per-(aggregator × layout) speedup of ``cur`` over ``base``
    (geometric mean across the (m, d) grid points both files share)."""
    def keyed(rows):
        return {(r["aggregator"], r["layout"], r["m"], r["d"]):
                r["us_per_call"] for r in rows}
    b, c = keyed(base["rows"]), keyed(cur["rows"])
    shared = sorted(set(b) & set(c))
    if not shared:
        print("# compare: no shared (aggregator, layout, m, d) rows")
        return
    for meta_of, tag in ((base, "base"), (cur, "cur ")):
        mt = meta_of.get("meta", {})
        print(f"# {tag}: backend={mt.get('backend', '?')} "
              f"jax={mt.get('jax_version', '?')} "
              f"rev={mt.get('git_rev', '?')} date={mt.get('date', '?')}")
    groups: dict = {}
    for k in shared:
        if c[k] > 0:                    # guard the division itself too
            groups.setdefault(k[:2], []).append(b[k] / c[k])
    print("aggregator,layout,n_points,speedup_geomean")
    for (agg, layout), ratios in sorted(groups.items()):
        gm = _geomean(ratios, f"compare {agg}/{layout}")
        print(f"{agg},{layout},{len(ratios)},{gm:.2f}x")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", nargs="+", metavar="BENCH_JSON",
                    help="one file: run, then print speedup vs it; "
                         "two files: diff OLD NEW without running")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="output path (default: repo BENCH_agg.json)")
    args = ap.parse_args()

    if args.compare and len(args.compare) == 2:
        old, new = (json.load(open(p)) for p in args.compare)
        compare(old, new)
        return 0
    if args.compare and len(args.compare) > 2:
        ap.error("--compare takes one or two files")
    # load the baseline BEFORE the run: --out may overwrite the very
    # file being compared against (the committed-BENCH use case)
    baseline = json.load(open(args.compare[0])) if args.compare else None

    rng = np.random.default_rng(0)
    rows, times, times_e = [], {}, {}
    fns, efns = {}, {}
    for name in sorted(A.AGGREGATORS):
        cfg = ByzantineConfig(aggregator=name, alpha=0.25)
        fns[name] = jax.jit(lambda G, c=cfg: A.aggregate(G, c))
        # elastic rows: the masked quorum-round path at 75% active
        # (quorum must satisfy the static q > 2*floor(alpha*q) bound)
        efns[name] = jax.jit(lambda G, act, c=cfg, n=name: engine
                             .aggregate_local(G, c, valid=act,
                                              spec=engine.get_spec(n)))

    print("aggregator,layout,m,d,us_per_call")
    for m in MS:
        for d in DS:
            G = jnp.asarray(rng.normal(size=(m, d)).astype("f4"))
            act = jnp.asarray(
                (np.arange(m) < int(0.75 * m)).astype("f4"))
            for name, fn in fns.items():
                us = time_fn(fn, G)
                times[(name, m, d)] = us
                rows.append({"aggregator": name, "layout": "local",
                             "m": m, "d": d, "us_per_call": us})
                print(f"{name},local,{m},{d},{us:.1f}", flush=True)
                ue = time_fn(efns[name], G, act)
                times_e[(name, m, d)] = ue
                rows.append({"aggregator": name, "layout": "elastic",
                             "m": m, "d": d, "us_per_call": ue})
                print(f"{name},elastic,{m},{d},{ue:.1f}", flush=True)

    for r in _distributed_rows():
        rows.append(r)
        print(f"{r['aggregator']},{r['layout']},{r['m']},{r['d']},"
              f"{r['us_per_call']:.1f}", flush=True)

    # scaling fits (log-log least squares)
    fits = {}
    for name in ("brsgd", "mean"):
        xs, ys = [], []
        for (n, m, d), us in times.items():
            if n == name and np.isfinite(us) and us > 0:
                xs.append([np.log(m), np.log(d), 1.0])
                ys.append(np.log(us))
        coef, *_ = np.linalg.lstsq(np.asarray(xs), np.asarray(ys), rcond=None)
        fits[name] = {"m_exp": float(coef[0]), "d_exp": float(coef[1])}
        print(f"# {name} scaling: time ~ m^{coef[0]:.2f} * d^{coef[1]:.2f}")

    # elastic-vs-bulk overhead: the masked path divided by the bulk
    # local path, geometric mean over the (m, d) grid per aggregator
    overhead = {}
    for name in sorted(A.AGGREGATORS):
        ratios = [times_e[k] / times[k] for k in times
                  if k[0] == name and k in times_e and times[k] > 0]
        overhead[name] = _geomean(ratios, f"{name} elastic/local")
        print(f"# {name} elastic/local overhead: x{overhead[name]:.2f}")

    # krum m-scaling at fixed d (expect ~quadratic at large m)
    d = DS[-1]
    r64_16 = times[("krum", 64, d)] / times[("krum", 16, d)]
    rb = times[("brsgd", 64, d)] / times[("brsgd", 16, d)]
    ok = rb < (r64_16 + 1) / 2 or rb < 8
    print(f"# m 16->64 (4x): krum x{r64_16:.1f} (O(m^2)->16x), "
          f"brsgd x{rb:.1f} (O(m)->4x)")
    print(f"# CLAIM brsgd O(md): {'PASS' if ok else 'FAIL'}")

    out = {"schema": SCHEMA, "meta": bench_meta(), "rows": rows,
           "fits": fits, "elastic_overhead": overhead,
           "krum_ratio_16_to_64": float(r64_16),
           "brsgd_ratio_16_to_64": float(rb), "claim_pass": bool(ok)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {os.path.normpath(args.out)} ({len(rows)} rows)")
    if baseline is not None:
        compare(baseline, out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
