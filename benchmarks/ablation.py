"""Ablation of the paper's two hyperparameters: the kept fraction beta
(Constraint 2) and the l1 threshold T (Constraint 1, auto vs fixed).

Strongly convex regression under a 20% scale attack (the rate.py
setup).  Expected structure:
  * beta in (alpha, 1/2]: robust, error flat — BrSGD is insensitive
    inside the valid range (the paper only requires alpha < beta <= 1/2);
  * beta = 1.0 (keep everyone, filter only by l1): the score filter is
    off; the l1 filter alone must carry the defense;
  * fixed huge T + beta=1.0 degenerates to the (broken) mean.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.core import aggregators, threat

D, STEPS, LR, M, N = 20, 120, 0.3, 20, 400


def run(bcfg: ByzantineConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=D).astype("f4") / np.sqrt(D)
    X = rng.normal(size=(M, N, D)).astype("f4")
    y = X @ w_star + 0.5 * rng.normal(size=(M, N)).astype("f4")
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    @jax.jit
    def step(w, key):
        G = jax.vmap(lambda Xi, yi: Xi.T @ (Xi @ w - yi) / N)(Xj, yj)
        G = threat.apply_dense(G, key, bcfg)
        return w - LR * aggregators.aggregate(G, bcfg)

    w = jnp.zeros(D, jnp.float32)
    key = jax.random.PRNGKey(seed)
    for t in range(STEPS):
        w = step(w, jax.random.fold_in(key, t))
    return float(jnp.linalg.norm(w - jnp.asarray(w_star)))


def main():
    print("beta,threshold,error")
    results = {}
    for beta in (0.3, 0.4, 0.5, 0.75, 1.0):
        for thr in (0.0, 1e9):      # 0.0 = auto median rule; 1e9 = off
            e = float(np.mean([run(ByzantineConfig(
                aggregator="brsgd", beta=beta, threshold=thr,
                attack="scale", alpha=0.2, scale_factor=50.0), seed=s)
                for s in range(3)]))
            results[(beta, thr)] = e
            print(f"{beta},{'auto' if thr == 0 else 'off'},{e:.4f}",
                  flush=True)
    # structure checks
    valid = [results[(b, 0.0)] for b in (0.3, 0.4, 0.5)]
    spread = max(valid) / max(min(valid), 1e-9)
    print(f"# beta-insensitivity inside (alpha, 1/2]: spread x{spread:.2f}")
    both_off = results[(1.0, 1e9)]
    l1_only = results[(1.0, 0.0)]
    score_only = results[(0.5, 1e9)]
    print(f"# l1-only error {l1_only:.3f}; score-only {score_only:.3f}; "
          f"both-off (mean) {both_off:.3f}")
    ok = (spread < 3.0 and both_off > 5 * max(l1_only, score_only, 1e-3))
    print(f"# CLAIM both constraints contribute, valid-range insensitive: "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
