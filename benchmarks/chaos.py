"""Chaos harness: randomized-but-seeded fault schedules over the full
train → checkpoint → serve loop, under an ACTIVE byzantine attack.

The faulted run drives the guarded elastic step through the recovery
supervisor (faults/supervisor.py) while a :class:`ChaosPlan` injects
host crashes, honest-worker NaN bursts, worker flapping and on-disk
checkpoint corruption; a fault-free control run uses the SAME
supervised config (so the comparison isolates the faults, not the
guard).  The serve phase replays the serve-scope faults — a corrupt
checkpoint publish (quarantined by the HotSwapper), a wedged decode
slot (requeued by the scheduler watchdog), a frozen swap source —
against the trained weights.

Recorded in ``BENCH_faults.json`` (validated by check_bench.py in CI):
per-fault MTTR (steps from onset to the next clean step), supervisor
counters, final-loss ratio vs the control run, zero-recompile proof
for both the train step and decode, and the serve completion /
requeue / quarantine counts.

  PYTHONPATH=src python benchmarks/chaos.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import (ARCHS, ByzantineConfig, RecoveryConfig,
                           TrainConfig)
from repro.data.pipeline import LMWorkerPipeline
from repro.faults import ChaosPlan, FaultEvent, Supervisor, Trigger, get_spec
from repro.launch.mesh import make_mesh, n_workers
from repro.models import params as PM
from repro.models import transformer as TF
from repro.serving import HotSwapper, ServeLoop
from repro.training.step import build_train_step
from serve_bench import bench_meta

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_faults.json")
FAULTS_SCHEMA = 1

CKPT_EVERY = 5


def make_plan(m: int, n_steps: int, seed: int) -> ChaosPlan:
    """The acceptance schedule: host crash + honest NaN burst + corrupt
    checkpoint in one run (ISSUE: >= 3 fault kinds under attack), plus
    a flapping worker and a torn checkpoint.  Targets sit OUTSIDE the
    byzantine prefix (alpha=0.25, m=8 -> byz workers 0..1) so the
    faults hit honest workers — breakage, not adversary."""
    return ChaosPlan([
        FaultEvent("host_crash", Trigger(at=6), workers=(6,)),
        FaultEvent("corrupt_ckpt", Trigger(at=11)),
        FaultEvent("nan_burst", Trigger(at=12, duration=2), workers=(5,)),
        FaultEvent("flap", Trigger(at=16, duration=3), workers=(4,)),
        FaultEvent("torn_ckpt", Trigger(at=21)),
    ], m=m, n_steps=n_steps, seed=seed)


def run_train(bundle, bsh, psh, tcfg, m, steps, seed, ckpt_dir, plan,
              params, opt_state):
    """One supervised run; ``plan=None`` is the fault-free control."""
    sup = Supervisor(bundle.step_fn, tcfg.byzantine, tcfg.recovery, m,
                     ckpt_dir=ckpt_dir, like=params, shardings=psh)
    pipe = LMWorkerPipeline(tcfg.model, m, 2, 32, seed=seed,
                            byz=tcfg.byzantine)
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    losses = []
    for step in range(steps):
        if plan is not None:
            for ev, spec in plan.fired(step):
                if spec.scope != "ckpt":
                    continue
                victims = ckpt.steps(ckpt_dir)
                if victims:
                    detail = spec.inject(ckpt_dir, victims[-1], rng)
                    sup._event(step, ev.fault, detail)
            active = plan.worker_mask(step)
            faults = plan.grad_faults(step)
        else:
            active, faults = np.ones(m, np.float32), None
        batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                 for k, v in pipe.batch(step).items()}
        params, opt_state, met = sup.run_step(
            params, opt_state, batch, step, jax.random.fold_in(key, step),
            sched_active=active, faults=faults)
        if met.get("step_ok"):
            losses.append(met["loss"])
        if (step + 1) % CKPT_EVERY == 0:
            sup.checkpoint(params, step + 1)
    final = float(np.mean(losses[-3:])) if losses else float("nan")
    finite = bool(all(np.isfinite(x).all()
                      for x in jax.tree.leaves(params)))
    return params, sup, final, finite


def mttr_rows(plan: ChaosPlan, sup: Supervisor) -> list:
    """Steps from each fault's onset to the next clean (ok) step."""
    rows = []
    for ev, at in plan.onsets():
        rec = next((e["step"] - at for e in sup.log
                    if e["step"] >= at and e["ok"]), None)
        rows.append({"fault": ev.fault, "at": at,
                     "steps_to_recover": rec})
    return rows


class ServeCtx:
    """The harness context serve-scope fault injects act on."""

    def __init__(self, loop, stall_ticks: int, stale_ticks: int):
        self.loop = loop
        self.stall_ticks = stall_ticks
        self.stale_ticks = stale_ticks
        self.frozen_until = -1

    def freeze(self, ticks: int) -> None:
        self.frozen_until = self.loop.ticks + ticks


def run_serve(cfg, params, gen: int, seed: int) -> dict:
    """Serve the trained weights under the serve-scope faults: one
    corrupt publish (quarantined), one wedged slot (requeued), one
    frozen swap window, then a good publish (swapped live)."""
    d = tempfile.mkdtemp(prefix="repro_chaos_serve_")
    ckpt.save(d, params, step=1)
    ckpt.mark_good(d, 1, like=params)
    swapper = HotSwapper(d, like=params)
    loop = ServeLoop(cfg, 4, 8 + gen, swapper=swapper, request_timeout=8)
    ctx = ServeCtx(loop, stall_ticks=16, stale_ticks=6)
    rng = np.random.default_rng(seed)
    n_req = 8
    for _ in range(n_req):
        loop.submit(rng.integers(0, cfg.vocab, size=8), max_new=gen)
    state = {"published": False}

    def on_step(lp, s):
        if s == 2:
            # a bad publish: lands complete, fails restore -> quarantine
            ckpt.save(d, jax.tree.map(lambda x: x * 1.01, params), step=2)
            get_spec("corrupt_ckpt").inject(d, 2, rng)
        elif s == 4:
            get_spec("slot_stall").inject(ctx, rng)
        elif s == 6:
            get_spec("stale_swap").inject(ctx, rng)
        elif s >= 8 and not state["published"]:
            if lp.ticks >= ctx.frozen_until:    # publisher unfroze
                ckpt.save(d, jax.tree.map(lambda x: x * 0.99, params),
                          step=3)
                state["published"] = True

    done = loop.run(on_step=on_step)
    snap = loop.metrics.snapshot()
    return {"requests": n_req,
            "completed": int(snap["requests_completed"]),
            "requeues": int(snap["requests_requeued"]),
            "quarantined_ckpts": len(swapper.quarantined),
            "swaps": swapper.swap_count,
            "decode_compiles": loop.decode_compiles()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer steps, shorter generations")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)
    steps = 24 if args.smoke else args.steps
    gen = 8 if args.smoke else 16

    mesh = make_mesh((8, 1), ("data", "model"))
    cfg = ARCHS[args.arch].reduced()
    m = n_workers(mesh, "global")
    quorum = 6
    bcfg = ByzantineConfig(aggregator="brsgd", attack="sign_flip",
                           alpha=0.25, membership="prefix",
                           max_m=m, quorum=quorum)
    # rollback_after=1: the NaN burst both evicts its worker AND forces
    # one rollback, whose last_good candidate is the checkpoint the
    # corrupt_ckpt fault just mutilated -- exercising the
    # skip-unrestorable path.  keep_ckpts=4 keeps an older good anchor.
    rcfg = RecoveryConfig(guard=True, rollback_after=1, keep_ckpts=4)
    tcfg = TrainConfig(model=cfg, byzantine=bcfg, optimizer="sgd",
                       lr=0.01, agg_scope="global", agg_layout="a2a",
                       recovery=rcfg)
    plan = make_plan(m, steps, args.seed)

    bundle = build_train_step(tcfg, mesh)
    psh, _, bsh = bundle.shardings(mesh)
    key = jax.random.PRNGKey(args.seed)
    init = lambda: jax.device_put(
        PM.init_params(TF.param_defs(cfg), key), psh)

    with mesh:
        # control first: it warms the jit cache the faulted run and the
        # zero-recompile assertion then ride on
        _, sup0, loss_clean, _ = run_train(
            bundle, bsh, psh, tcfg, m, steps, args.seed,
            tempfile.mkdtemp(prefix="repro_chaos_clean_"), None,
            init(), ())
        steady = bundle.step_fn._cache_size()
        params, sup, loss_faulted, finite = run_train(
            bundle, bsh, psh, tcfg, m, steps, args.seed,
            tempfile.mkdtemp(prefix="repro_chaos_fault_"), plan,
            init(), ())
        zero_recompiles = bundle.step_fn._cache_size() == steady
    print(f"train: clean={loss_clean:.4f} faulted={loss_faulted:.4f} "
          f"finite={finite} recompiles={not zero_recompiles} "
          f"{sup.summary() | {'events': '...'}}")

    serve = run_serve(cfg, params, gen, args.seed)
    print(f"serve: {serve}")

    ratio = loss_faulted / loss_clean
    checks = {
        "params_finite": finite,
        "zero_recompiles": zero_recompiles,
        "loss_ratio_le_2": bool(np.isfinite(ratio) and ratio <= 2.0),
        "evicted_and_recovered": sup.evictions >= 1,
        "rolled_back": sup.rollbacks >= 1,
        "all_requests_completed": serve["completed"] == serve["requests"],
        "requeued_then_completed": serve["requeues"] >= 1,
        "ckpt_quarantined": serve["quarantined_ckpts"] >= 1,
        "one_decode_compile": serve["decode_compiles"] == 1,
    }
    bench = {
        "schema": FAULTS_SCHEMA, "kind": "faults", "meta": bench_meta(),
        "arch": cfg.name, "m": m, "quorum": quorum,
        "aggregator": bcfg.aggregator, "attack": bcfg.attack,
        "alpha": bcfg.alpha, "steps": steps, "seed": args.seed,
        "plan": plan.describe(),
        "train": {
            "params_finite": finite,
            "loss_clean": loss_clean,
            "loss_faulted": loss_faulted,
            "loss_ratio": float(ratio),
            "zero_recompiles": zero_recompiles,
            "steady_cache": steady,
            "mttr": mttr_rows(plan, sup),
            **{k: v for k, v in sup.summary().items() if k != "events"},
        },
        "serve": serve,
        "checks": checks,
        "claim": "PASS" if all(checks.values()) else "FAIL",
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"{bench['claim']}: wrote {args.out}")
    if bench["claim"] != "PASS":
        raise SystemExit(f"chaos run failed: "
                         f"{[k for k, v in checks.items() if not v]}")
    return bench


if __name__ == "__main__":
    main()
