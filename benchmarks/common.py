"""Shared harness for the paper-repro benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ByzantineConfig
from repro.configs.lenet_fmnist import LeNetConfig
from repro.core.simulate import make_sim_step
from repro.data.pipeline import ImageWorkerPipeline
from repro.models import lenet
from repro.models.params import init_params

M = 20   # paper: 20 workers


def train_lenet(aggregator: str, attack: str, alpha: float, steps: int = 60,
                lr: float = 0.05, seed: int = 0, batch: int = 8,
                record_every: int = 5):
    """One paper-style run.  Returns (final_acc, curve[(step, acc)])."""
    cfg = LeNetConfig()
    bcfg = ByzantineConfig(aggregator=aggregator, attack=attack, alpha=alpha)
    pipe = ImageWorkerPipeline(M, n_per_worker=128, seed=seed, byz=bcfg)
    params = init_params(lenet.lenet_defs(cfg), jax.random.PRNGKey(seed))
    step_fn = make_sim_step(lambda p, b: lenet.lenet_loss(p, b), bcfg, lr)
    key = jax.random.PRNGKey(seed + 1)
    test_x = jnp.asarray(pipe.test_images[:512])
    test_y = jnp.asarray(pipe.test_labels[:512])
    curve = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(s, batch).items()}
        params, _ = step_fn(params, b, jax.random.fold_in(key, s))
        if s % record_every == 0 or s == steps - 1:
            acc = float(lenet.lenet_accuracy(params, test_x, test_y))
            if not np.isfinite(np.asarray(
                    jax.tree.leaves(params)[0]).sum()):
                acc = float("nan")
            curve.append((s, acc))
    return curve[-1][1], curve


def time_fn(fn, *args, reps: int = 5, warmup: int = 2):
    """Median wall-time (us) of jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
