"""Roofline report: renders EXPERIMENTS.md §Dry-run / §Roofline tables
from the JSON records produced by ``repro.launch.dryrun``."""
from __future__ import annotations

import json
import pathlib
import sys

DEFAULT = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(out: pathlib.Path, mesh: str = "single", tag: str = ""):
    rows = []
    for f in sorted(out.glob("*.json")):
        r = json.loads(f.read_text())
        parts = f.stem.split("__")
        rtag = parts[3] if len(parts) > 3 else ""
        if r.get("mesh") != mesh or rtag != tag:
            continue
        rows.append(r)
    return rows


def fmt_table(rows):
    out = ["| arch | shape | scope/layout | compute s | memory s | coll s | "
           "dominant | model TF | useful | bound-MFU |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r.get('arch')} | {r.get('shape')} | FAIL |||||||")
            continue
        rl = r["roofline"]
        sl = r.get("scope", r["mode"])
        if r.get("layout"):
            sl += "/" + r["layout"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {sl} "
            f"| {rl['compute_s']:.3e} | {rl['memory_s']:.3e} "
            f"| {rl['collective_s']:.3e} | {rl['dominant']} "
            f"| {rl['model_flops']/1e12:.1f} | {rl['useful_ratio']:.2f} "
            f"| {rl['mfu_bound']*100:.1f}% |")
    return "\n".join(out)


def main():
    out = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT
    rows = load(out, "single")
    if not rows:
        print("no dry-run records found; run `python -m repro.launch.dryrun --all`")
        return 1
    print("## Roofline (single-pod 16x16, per-device terms)\n")
    print(fmt_table(rows))
    multi = load(out, "multi")
    n_ok = sum(1 for r in multi if r.get("ok"))
    print(f"\nmulti-pod (2x16x16): {n_ok}/{len(multi)} cases compiled ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
