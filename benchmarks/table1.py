"""Paper Table 1: test accuracy of LeNet under four Byzantine attacks at
alpha in {0, 10%, 25%, 50%} for {brsgd, median, mean, krum}.

Reduced-step CPU repro on the synthetic FashionMNIST-like set — the
VALIDATION TARGET is the paper's qualitative structure (DESIGN.md §8):
  * brsgd ~ attack-free baseline at every alpha,
  * mean collapses under gaussian/negation,
  * krum degrades at alpha=50%.
"""
from __future__ import annotations

import sys

from .common import train_lenet

ATTACKS = ["gaussian", "negation", "scale", "label_flip"]
# 0.45 stands in for the paper's "50%" row: the theory (and the honest-
# majority assumption) requires alpha <= 1/2 - eps, and at EXACTLY m/2
# identical attackers the coordinate median sits midway between the two
# clusters — per-dimension the honest and byzantine sides are symmetric,
# so no median-based rule (the paper's included) can separate them.
# alpha=0.50 is still RUN and reported, but excluded from the PASS gate;
# see EXPERIMENTS.md §Paper.
ALPHAS = [0.10, 0.25, 0.45, 0.50]
GATED_ALPHAS = [0.10, 0.25, 0.45]
AGGS = ["brsgd", "median", "mean", "krum"]


def main(steps: int = 60):
    base, _ = train_lenet("mean", "none", 0.0, steps=steps)
    print(f"baseline(alpha=0, mean): acc={base:.3f}")
    print("aggregator,attack,alpha,accuracy")
    rows = {}
    for agg in AGGS:
        for attack in ATTACKS:
            for alpha in ALPHAS:
                if agg == "krum" and alpha >= 0.5:
                    # krum needs m - f - 2 >= 1 honest margin; alpha=0.5
                    # is run to show the degradation, f capped inside
                    pass
                acc, _ = train_lenet(agg, attack, alpha, steps=steps)
                rows[(agg, attack, alpha)] = acc
                print(f"{agg},{attack},{alpha:.2f},{acc:.3f}", flush=True)
    # structural checks (soft: printed, not raised, except brsgd)
    worst_brsgd = min(v for (a, _, al), v in rows.items()
                      if a == "brsgd" and al in GATED_ALPHAS)
    worst_half = min(v for (a, _, al), v in rows.items()
                     if a == "brsgd" and al == 0.50)
    print(f"# brsgd worst-case acc (alpha<1/2): {worst_brsgd:.3f} "
          f"(baseline {base:.3f}); at the alpha=1/2 boundary: {worst_half:.3f}")
    ok = worst_brsgd > base - 0.2
    print(f"# CLAIM brsgd~baseline at all alpha: {'PASS' if ok else 'FAIL'}")
    mean_gauss = rows[("mean", "gaussian", 0.25)]
    print(f"# CLAIM mean collapses (gaussian 25%): "
          f"{'PASS' if (mean_gauss != mean_gauss or mean_gauss < base - 0.2) else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    sys.exit(main(steps))
